"""Fixed-slot SPSC rings over a shared-memory arena (queue pairs, §IV-C).

One :class:`Ring` is a single-producer/single-consumer ring of ``n_slots``
fixed-size slots living inside a :class:`~repro.ipc.shm.SharedMemoryArena`.
Each slot is::

    [ slot header (64 B) | meta region (meta_bytes) | payload (slot_bytes) ]

with the header holding the slot *state flag* — the paper's completion flag —
plus the published payload/meta lengths and a monotonically increasing
message sequence number.  The producer cycles tail→slots, the consumer
head→slots; the state flag is the only synchronization point:

    EMPTY --producer--> WRITING --publish--> READY --consumer--> READING
      ^                                                              |
      +-------------------------- release --------------------------+

Completion waits use the repo's hybrid polling (``core.latency`` +
``core.policy``): optional size-aware deferral (sleep most of the predicted
copy latency) followed by short passive waits of ``poll_interval_us`` — the
UMWAIT-quantum analogue.  Pre-mapping is inherited from the arena: all slots
are first-touched at creation, so steady state never faults.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.latency import LatencyModel
from repro.core.policy import OffloadPolicy
from repro.ft import inject as _inject
from repro.ipc.shm import SharedMemoryArena
from repro.obs import trace as _trace

SLOT_HEADER_BYTES = 64
_ALIGN = 64

# slot states (int64 stores — single aligned word, untorn)
EMPTY, WRITING, READY, READING = 0, 1, 2, 3

# message-kind flags (slot header word 4, published with the state flip):
# FLAG_HEAP marks a large message whose payload lives in bulk-heap extents
# (ipc/heap.py); the slot carries only the compact extent descriptor.
# FLAG_COALESCED marks a microbatch frame: the slot carries K independent
# sub-messages (sub-message table in the meta region, payloads packed
# back-to-back) published under ONE state flip — the small-message fast
# path that amortizes slot claim, meta encode, and doorbell K-ways.
# FLAG_CRC marks a slot whose header word 5 carries a CRC32 over the
# published meta bytes (OffloadPolicy.meta_checksum): the receiver
# verifies before decoding and quarantines mismatches as counted
# ``corrupt_drops`` instead of crashing the drain loop.
FLAG_HEAP = 1
FLAG_COALESCED = 2
FLAG_CRC = 4


class ChannelClosed(EOFError):
    """The peer endpoint shut down while we were waiting on the ring."""


def _align(n: int, a: int = _ALIGN) -> int:
    return (n + a - 1) // a * a


@dataclass(frozen=True)
class RingSpec:
    """Geometry of one ring; both endpoints must construct from the same
    spec (the transport embeds it in the arena descriptor)."""
    n_slots: int
    slot_bytes: int            # payload capacity per slot
    meta_bytes: int = 1024     # per-slot metadata capacity (pickled headers)

    @property
    def slot_stride(self) -> int:
        """Bytes from one slot's header to the next (64B-aligned regions)."""
        return SLOT_HEADER_BYTES + _align(self.meta_bytes) + \
            _align(self.slot_bytes)

    @property
    def region_bytes(self) -> int:
        """Total arena bytes this ring occupies."""
        return self.n_slots * self.slot_stride


@dataclass
class RingStats:
    """Per-endpoint ring counters (local; shared counts live in the arena)."""
    produced: int = 0
    consumed: int = 0
    polls: int = 0
    full_waits: int = 0          # producer found ring full (backpressure)
    deferred_sleep_s: float = 0.0
    blocked_wait_s: float = 0.0


class _Slot:
    """Typed views over one slot's header/meta/payload regions."""

    def __init__(self, arena: SharedMemoryArena, offset: int, spec: RingSpec):
        self.hdr = arena.ndarray(offset, (8,), np.int64)   # state, seq, pay, meta, flags
        meta_off = offset + SLOT_HEADER_BYTES
        self.meta_view = arena.view(meta_off, spec.meta_bytes)
        pay_off = meta_off + _align(spec.meta_bytes)
        self.payload_view = arena.view(pay_off, spec.slot_bytes)

    # header word accessors (index names double as layout docs)
    @property
    def state(self) -> int:
        return int(self.hdr[0])

    @state.setter
    def state(self, v: int) -> None:
        self.hdr[0] = v

    @property
    def seq(self) -> int:
        return int(self.hdr[1])

    @seq.setter
    def seq(self, v: int) -> None:
        self.hdr[1] = v

    @property
    def payload_nbytes(self) -> int:
        return int(self.hdr[2])

    @payload_nbytes.setter
    def payload_nbytes(self, v: int) -> None:
        self.hdr[2] = v

    @property
    def meta_nbytes(self) -> int:
        return int(self.hdr[3])

    @meta_nbytes.setter
    def meta_nbytes(self, v: int) -> None:
        self.hdr[3] = v

    @property
    def flags(self) -> int:
        return int(self.hdr[4])

    @flags.setter
    def flags(self, v: int) -> None:
        self.hdr[4] = v

    def drop_views(self) -> None:
        """Release buffer exports so the arena can close."""
        self.hdr = None
        self.meta_view = None
        self.payload_view = None


class SlotWriter:
    """Producer-side lease on a WRITING slot; ``publish`` flips it READY.

    This is the ring's **reserve-then-fill** primitive: ``Ring.acquire``
    reserves the slot, the caller fills ``payload``/``meta`` in place
    (e.g. packing a reply straight into the destination slot with no
    staging copy), and ``publish`` is the doorbell.  ``abort`` releases a
    reserved slot that cannot be filled: it publishes a zero-meta
    sentinel the data-channel receive path silently skips, so the SPSC
    cursor chain stays intact (a plain state rollback would strand the
    consumer, which waits on slots strictly in order)."""

    def __init__(self, ring: "Ring", slot: _Slot, seq: int):
        self._ring = ring
        self.slot = slot
        self.seq = seq

    @property
    def payload(self) -> memoryview:
        """Writable view over the slot's full payload region."""
        return self.slot.payload_view

    @property
    def meta(self) -> memoryview:
        """Writable view over the slot's metadata region."""
        return self.slot.meta_view

    def publish(self, payload_nbytes: int, meta_nbytes: int = 0,
                flags: int = 0, meta_crc: int = -1) -> None:
        """Flip the slot READY — the paper's completion-flag store.

        ``flags`` is the message-kind word (:data:`FLAG_HEAP`: the payload
        lives in bulk-heap extents named by the meta, ``payload_nbytes``
        then counts *heap* bytes and the slot payload region is unused).
        Always stored, so slot reuse cannot leak a stale flag.

        ``meta_crc >= 0`` stores a CRC32 of the meta bytes in header
        word 5 and raises :data:`FLAG_CRC`, published atomically with the
        state flip (the checksum rides the same doorbell it guards)."""
        s = self.slot
        if _inject._PLANE is not None and meta_nbytes > 0:
            if _inject.fire("ring.publish.drop") is not None:
                # the message vanishes in flight: publish the zero-meta
                # skip sentinel so the SPSC cursor chain stays intact
                payload_nbytes = meta_nbytes = flags = 0
                meta_crc = -1
            else:
                torn = _inject.fire("ring.publish.torn")
                if torn is not None:
                    s.meta_view[0] ^= (torn.arg or 0xFF) & 0xFF
        if meta_crc >= 0:
            s.hdr[5] = meta_crc
            flags |= FLAG_CRC
        s.payload_nbytes = payload_nbytes
        s.meta_nbytes = meta_nbytes
        s.flags = flags
        s.seq = self.seq
        s.state = READY            # the publishing store (completion flag)
        self._ring._produced[0] += 1
        self._ring.stats.produced += 1

    def abort(self) -> None:
        """Give the reserved slot back as a skip sentinel (zero meta)."""
        self.publish(0, 0, 0)


class SlotReader:
    """Consumer-side lease on a READING slot; ``release`` frees it."""

    def __init__(self, ring: "Ring", slot: _Slot):
        self._ring = ring
        self.slot = slot
        self.seq = slot.seq
        self.payload_nbytes = slot.payload_nbytes
        self.meta_nbytes = slot.meta_nbytes
        self.flags = slot.flags
        # published meta checksum (valid only when flags & FLAG_CRC)
        self.meta_crc = int(slot.hdr[5]) if (self.flags & FLAG_CRC) else -1

    @property
    def payload(self) -> memoryview:
        """Read-only view of the published payload bytes (zero-copy)."""
        return self.slot.payload_view[:self.payload_nbytes]

    @property
    def meta(self) -> bytes:
        """The published metadata bytes (copied out; they are small)."""
        return bytes(self.slot.meta_view[:self.meta_nbytes])

    def payload_array(self, offset: int, shape, dtype,
                      copy: bool = True) -> np.ndarray:
        """Typed view (or copy) of a sub-range of the payload."""
        dtype = np.dtype(dtype)
        count = math.prod(shape)
        arr = np.frombuffer(self.slot.payload_view, dtype, count=count,
                            offset=offset).reshape(shape)
        return arr.copy() if copy else arr

    def release(self) -> None:
        """Recycle the slot (EMPTY): any payload views become invalid.

        Safe after transport teardown: if the endpoint was closed while
        this lease was still held (a reaped connection whose requests were
        queued in the dispatcher), the slot views are already dropped and
        there is nothing to recycle — releasing is a no-op rather than a
        crash in whoever held the lease."""
        try:
            self.slot.state = EMPTY
            self._ring._consumed[0] += 1
        except TypeError:              # drop_views() ran: slot/counters gone
            return
        self._ring.stats.consumed += 1

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.release()


class Ring:
    """One directional ring endpoint (construct with the producer or
    consumer role; both map the same arena region)."""

    def __init__(self, arena: SharedMemoryArena, offset: int, spec: RingSpec,
                 policy: Optional[OffloadPolicy] = None,
                 latency: Optional[LatencyModel] = None,
                 counter_words: tuple[int, int] = (4, 5)):
        self.arena = arena
        self.spec = spec
        self.policy = policy or OffloadPolicy()
        self.latency = latency or LatencyModel()
        self.stats = RingStats()
        self._slots = [
            _Slot(arena, offset + i * spec.slot_stride, spec)
            for i in range(spec.n_slots)
        ]
        # shared produced/consumed counters (introspection + wraparound tests)
        words = arena.control_words()
        self._produced = words[counter_words[0]:counter_words[0] + 1]
        self._consumed = words[counter_words[1]:counter_words[1] + 1]
        self._head = 0             # consumer cursor (local: SPSC)
        self._tail = 0             # producer cursor (local: SPSC)
        self._seq = 0
        self._closed_word: Optional[np.ndarray] = None

    def bind_shutdown_word(self, word: np.ndarray) -> None:
        """A shared flag checked inside waits: nonzero → peer is gone."""
        self._closed_word = word

    def _peer_closed(self) -> bool:
        return self._closed_word is not None and int(self._closed_word[0]) != 0

    @property
    def peer_closed(self) -> bool:
        """True once the bound shutdown word says the peer endpoint is gone
        (public so channel layers can surface :class:`ChannelClosed`
        consistently instead of poking ring internals)."""
        return self._peer_closed()

    @property
    def produced(self) -> int:
        """Messages published into this ring (shared counter)."""
        return int(self._produced[0])

    @property
    def consumed(self) -> int:
        """Messages released from this ring (shared counter)."""
        return int(self._consumed[0])

    # -- hybrid polling core --------------------------------------------------
    def _wait_state(self, slot: _Slot, want: int, timeout_s: float,
                    hint_nbytes: int = 0) -> bool:
        """Wait for ``slot.state == want`` with deferral + short waits."""
        if slot.state == want:
            return True
        if _trace.TRACE.enabled:           # slow path only: fast path above
            tt0 = _trace.now()
            ok = self._wait_state_slow(slot, want, timeout_s, hint_nbytes)
            _trace.emit(_trace.RING_WAIT, tt0, arg=hint_nbytes)
            return ok
        return self._wait_state_slow(slot, want, timeout_s, hint_nbytes)

    def _wait_state_slow(self, slot: _Slot, want: int, timeout_s: float,
                         hint_nbytes: int) -> bool:
        """Deferral + spin + passive-quantum body of :meth:`_wait_state`."""
        t0 = time.perf_counter()
        if hint_nbytes > 0:
            # size-aware deferral: sleep most of the predicted copy latency
            defer = self.latency.defer_seconds(hint_nbytes,
                                               self.policy.defer_fraction)
            if defer > 0:
                time.sleep(min(defer, timeout_s))
                self.stats.deferred_sleep_s += min(defer, timeout_s)
            if slot.state == want:
                return True
        # spin phase: yield-only polls so a streaming peer is caught at
        # memcpy latency even where sleep() granularity is ~1ms
        spin_deadline = time.perf_counter() + self.policy.spin_us * 1e-6
        while time.perf_counter() < spin_deadline:
            self.stats.polls += 1
            if slot.state == want:
                self.stats.blocked_wait_s += time.perf_counter() - t0
                return True
            time.sleep(0)
        quantum = self.policy.poll_interval_us * 1e-6
        deadline = t0 + timeout_s
        while slot.state != want:
            self.stats.polls += 1
            if self._peer_closed():
                raise ChannelClosed("peer endpoint closed the transport")
            if time.perf_counter() > deadline:
                self.stats.blocked_wait_s += time.perf_counter() - t0
                return False
            time.sleep(quantum)      # passive short wait (UMWAIT analogue)
        self.stats.blocked_wait_s += time.perf_counter() - t0
        return True

    # -- producer side --------------------------------------------------------
    def try_acquire(self) -> Optional[SlotWriter]:
        """Claim the next slot without blocking; None while the ring is full."""
        slot = self._slots[self._tail % self.spec.n_slots]
        if slot.state != EMPTY:
            return None
        slot.state = WRITING
        self._tail += 1
        self._seq += 1
        return SlotWriter(self, slot, self._seq)

    def acquire(self, timeout_s: float = 30.0) -> SlotWriter:
        """Claim the next slot, blocking while the ring is full
        (backpressure = the paper's bounded queue-pair depth)."""
        slot = self._slots[self._tail % self.spec.n_slots]
        if slot.state != EMPTY:
            self.stats.full_waits += 1
            if not self._wait_state(slot, EMPTY, timeout_s):
                raise TimeoutError(
                    f"ring full for {timeout_s}s (consumer stalled?)")
        slot.state = WRITING
        self._tail += 1
        self._seq += 1
        return SlotWriter(self, slot, self._seq)

    # -- consumer side --------------------------------------------------------
    def try_poll(self) -> Optional[SlotReader]:
        """Take the next READY slot without blocking; None when empty."""
        if _inject._PLANE is not None:
            _inject.stall("ring.poll.stall")
        slot = self._slots[self._head % self.spec.n_slots]
        if slot.state != READY:
            return None
        slot.state = READING
        self._head += 1
        return SlotReader(self, slot)

    def wait_recv(self, timeout_s: float = 30.0,
                  hint_nbytes: int = 0) -> SlotReader:
        """Block (hybrid polling) until a message is READY and lease it."""
        if _inject._PLANE is not None:
            _inject.stall("ring.poll.stall")
        slot = self._slots[self._head % self.spec.n_slots]
        if not self._wait_state(slot, READY, timeout_s, hint_nbytes):
            raise TimeoutError(f"no message within {timeout_s}s")
        slot.state = READING
        self._head += 1
        return SlotReader(self, slot)

    def drop_views(self) -> None:
        """Release every buffer export so the arena can be closed."""
        for s in self._slots:
            s.drop_views()
        self._produced = None
        self._consumed = None
        self._closed_word = None
