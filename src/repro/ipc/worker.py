"""Process runners over the shm transport: batch producers + RPC bridge.

Two roles:

- :func:`start_producer` spawns a **producer process** that attaches to a
  transport by name and streams source batches through the data channel —
  the real-IPC version of the input pipeline's producer side.  The control
  channel carries ``seek`` / ``stop`` commands back to the producer
  (checkpoint-restore and shutdown), and the producer marks end-of-stream
  with an ``eof`` header.

- :class:`DispatcherServer` / :class:`RemoteDispatcherClient` bridge the
  in-process :class:`~repro.core.dispatcher.RequestDispatcher` across the
  transport, so clients in *other processes* issue
  ``request(op, data, mode)`` / ``query(job_id)`` exactly like the paper's
  Listing 1 — sync blocks for the result, async/pipelined return a job id
  completed by hybrid polling (reusing :class:`QueryHandler`).

- :class:`ServingFabric` is the multi-client generalization: a listener
  accepts any number of clients, a reactor multiplexes their transports in
  one thread, and pipelined requests from *different processes* are packed
  into single dispatcher batches (cross-client batch formation), replies
  demultiplexed by completion callback.  Clients reach it with
  :meth:`RemoteDispatcherClient.connect`.

Producer entry points are module-level functions (spawn-safe).
"""
from __future__ import annotations

import importlib
import multiprocessing as mp
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.dispatcher import QueryHandler, Request, RequestDispatcher
from repro.core.latency import LatencyModel
from repro.core.policy import ExecutionMode, OffloadPolicy
from repro.ipc.ring import ChannelClosed
from repro.ipc.transport import ShmTransport, TransportSpec
from repro.obs import trace as _trace
from repro.obs.metrics import MetricsRegistry, SLOTracker


# ---------------------------------------------------------------------------
# source construction inside the producer process
# ---------------------------------------------------------------------------

def make_source_from_spec(spec: dict):
    """Build a batch source in the child from a picklable spec dict.

    kinds:
      ``synthetic_lm``  — repro.data.SyntheticLMSource(cfg, shape, seed, ...)
      ``factory``       — dotted ``module:function`` called with ``kwargs``
    """
    kind = spec.get("kind", "synthetic_lm")
    if kind == "synthetic_lm":
        from repro.data.pipeline import SyntheticLMSource
        return SyntheticLMSource(spec["cfg"], spec["shape"],
                                 seed=spec.get("seed", 0),
                                 batch_override=spec.get("batch_override"))
    if kind == "factory":
        mod_name, fn_name = spec["path"].split(":")
        fn = getattr(importlib.import_module(mod_name), fn_name)
        return fn(**spec.get("kwargs", {}))
    raise ValueError(f"unknown source kind {kind!r}")


def _producer_entry(name: str, source_spec: dict, policy: OffloadPolicy,
                    n_batches: Optional[int]) -> None:
    """Child main: attach, stream batches, honor seek/stop commands."""
    transport = ShmTransport.attach(name, policy=policy)
    source = make_source_from_spec(source_spec)
    state = {"it": iter(source), "gen": 0}

    def apply_seek(cmd: dict) -> None:
        # gen: seek generation, lets the consumer discard stale in-flight
        # batches published before the restore
        source.restore({"seed": cmd.get("seed", source.seed),
                        "step": cmd["step"]})
        state["it"] = iter(source)
        state["gen"] = cmd.get("gen", state["gen"] + 1)
        transport.data.flush()

    try:
        while True:
            sent = 0
            while n_batches is None or sent < n_batches:
                cmd = transport.ctrl.try_recv_msg()
                if cmd is not None:
                    if cmd.get("cmd") == "stop":
                        return
                    if cmd.get("cmd") == "seek":
                        apply_seek(cmd)
                        continue
                step = getattr(source, "step", sent)
                batch = next(state["it"])
                # mode semantics come from the policy: sync publishes
                # inline, async/pipelined overlap production with the copy
                transport.send(batch, header={"step": step,
                                              "gen": state["gen"]})
                sent += 1
            transport.data.flush()
            transport.send({}, header={"eof": True, "gen": state["gen"]},
                           mode="sync")
            # linger: a late stop makes the consumer's close racefree, and a
            # late seek (restore on a finished stream) restarts production
            deadline = time.perf_counter() + 30.0
            resumed = False
            while time.perf_counter() < deadline:
                cmd = transport.ctrl.try_recv_msg()
                if cmd is not None:
                    if cmd.get("cmd") == "stop":
                        return
                    if cmd.get("cmd") == "seek":
                        apply_seek(cmd)
                        resumed = True
                        break
                time.sleep(0.005)
            if not resumed:
                return
    except ChannelClosed:
        pass
    finally:
        transport.close()


@dataclass
class ProducerHandle:
    """Consumer-side handle on a spawned producer process."""
    transport: ShmTransport
    process: mp.process.BaseProcess
    gen: int = 0                 # current seek generation (0 = initial stream)

    def recv_batch(self, timeout_s: float = 60.0):
        """Next (batch, header); header["eof"] marks end of stream."""
        return self.transport.recv(timeout_s=timeout_s)

    def seek(self, step: int, seed: Optional[int] = None) -> int:
        """Reposition the producer; returns the new generation.  Batches
        already in flight carry the old generation — discard headers whose
        ``gen`` differs (stale data, possibly from a different seed)."""
        self.gen += 1
        msg = {"cmd": "seek", "step": step, "gen": self.gen}
        if seed is not None:
            msg["seed"] = seed
        self.transport.send_msg(msg)
        return self.gen

    def stop(self, timeout_s: float = 10.0) -> None:
        """Stop the producer (command, then closed-flag, then terminate)."""
        try:
            if self.process.is_alive():
                self.transport.send_msg({"cmd": "stop"}, timeout_s=2.0)
        except (TimeoutError, ChannelClosed, ValueError):
            pass
        # raise our closed flag first: a producer blocked on a full ring
        # sees ChannelClosed instead of waiting out its acquire timeout
        self.transport.announce_close()
        self.process.join(timeout=timeout_s)
        if self.process.is_alive():
            self.process.terminate()
            self.process.join(timeout=5)
        self.transport.close()


def start_producer(source_spec: dict,
                   policy: Optional[OffloadPolicy] = None,
                   spec: TransportSpec = TransportSpec(),
                   n_batches: Optional[int] = None,
                   name: Optional[str] = None,
                   ctx: Optional[mp.context.BaseContext] = None
                   ) -> ProducerHandle:
    """Create a transport and spawn a producer process streaming into it."""
    policy = policy or OffloadPolicy()
    transport = ShmTransport.create(name, spec, policy)
    ctx = ctx or mp.get_context("spawn")
    proc = ctx.Process(target=_producer_entry,
                       args=(transport.name, source_spec, policy, n_batches),
                       daemon=True)
    proc.start()
    return ProducerHandle(transport, proc)


# ---------------------------------------------------------------------------
# cross-process dispatcher bridge (paper Listing 1 across a real boundary)
# ---------------------------------------------------------------------------

class DispatcherServer:
    """Serves a :class:`RequestDispatcher`'s handlers to a remote client."""

    def __init__(self, dispatcher: RequestDispatcher,
                 transport: ShmTransport, workers: int = 2):
        self.dispatcher = dispatcher
        self.transport = transport
        self._pool = ThreadPoolExecutor(max_workers=workers,
                                        thread_name_prefix="rocket-ipc-srv")
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _reply(self, job_id: int, result, error: Optional[str]) -> None:
        tree = {} if error is not None else {"result": np.asarray(result)}
        self.transport.send(tree, header={"job_id": job_id, "error": error},
                            mode="sync")

    def _handle(self, header: dict, tree) -> None:
        job_id, op = header["job_id"], header["op"]
        mode = ExecutionMode(header.get("mode", "sync"))
        try:
            # route through the dispatcher so batching/stats apply; sync here
            # is fine — concurrency comes from the server pool
            if mode == ExecutionMode.SYNC:
                result = self.dispatcher.request(op, tree["data"], mode="sync")
            else:
                jid = self.dispatcher.request(op, tree["data"], mode=mode)
                result = self.dispatcher.query(jid)
            self._reply(job_id, result, None)
        except Exception as e:                      # surfaced client-side
            self._reply(job_id, None, f"{type(e).__name__}: {e}")

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                tree, header = self.transport.recv(timeout_s=0.05)
            except TimeoutError:
                continue
            except ChannelClosed:
                break
            if header.get("shutdown"):
                break
            self._pool.submit(self._handle, header, tree)

    def serve_forever(self) -> None:
        """Serve on the caller's thread until shutdown/close."""
        self._loop()

    def start(self) -> "DispatcherServer":
        """Serve from a background daemon thread."""
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="rocket-ipc-serve")
        self._thread.start()
        return self

    def close(self) -> None:
        """Stop the serve loop and drain the handler pool."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
        self._pool.shutdown(wait=True)


class ServingFabric:
    """Multi-client serving: listener + reactor + one shared dispatcher.

    The paper's server generalized from one queue pair to N (§IV-C at
    fleet scale): a :class:`~repro.ipc.listener.Listener` accepts client
    registrations and mints each one a dedicated transport; a
    :class:`~repro.ipc.reactor.Reactor` multiplexes all of them in one
    thread with round-robin fairness; and every drained request is fed to
    *one* :class:`RequestDispatcher`, so pipelined requests arriving from
    **different processes** inside the batching window are packed into a
    single handler call (cross-client batch formation) and the results are
    demultiplexed back to the right transports by completion callbacks.

    The large-message datapath is transparent here: a client request (or a
    server reply) at/over ``policy.heap_threshold_bytes`` rides the
    connection's bulk-heap extents instead of a ring slot, so request and
    reply sizes are bounded by heap geometry (``spec.heap_extents ×
    spec.heap_extent_bytes`` per direction), not by ``data_slot_bytes``.

    Teardown order matters and is owned by :meth:`close` (one ``with``
    block instead of a tuple of things to unwind): stop accepting, stop
    the sweep, flag every client, close transports, then the dispatcher.
    """

    def __init__(self, dispatcher: RequestDispatcher,
                 name: Optional[str] = None,
                 spec: TransportSpec = TransportSpec(),
                 policy: Optional[OffloadPolicy] = None,
                 latency: Optional[LatencyModel] = None,
                 max_clients: int = 64,
                 max_drain_per_sweep: int = 8,
                 max_inflight: int = 16,
                 reply_timeout_s: float = 5.0,
                 own_dispatcher: bool = False):
        from repro.ipc.listener import Listener
        from repro.ipc.reactor import Reactor

        self.dispatcher = dispatcher
        self.policy = policy or dispatcher.policy
        self.reply_timeout_s = reply_timeout_s
        self._own_dispatcher = own_dispatcher
        self.reactor = Reactor(self.policy, on_messages=self._on_messages,
                               max_drain_per_sweep=max_drain_per_sweep,
                               max_inflight=max_inflight)
        self.listener = Listener(name, spec, self.policy, latency,
                                 max_clients=max_clients,
                                 on_accept=self.reactor.add)
        # unified metrics plane: every stats surface in the fabric behind
        # one flat snapshot, plus the per-request SLO monitor (previously
        # orphaned ft/monitor.py + core/latency.py, now fed by replies)
        self.slo = SLOTracker(latency or getattr(dispatcher, "latency", None))
        self.metrics = MetricsRegistry()
        self.metrics.register("reactor", lambda: self.reactor.stats)
        self.metrics.register("dispatcher", lambda: self.dispatcher.stats)
        self.metrics.register("slo", self.slo)
        self.metrics.register(
            "listener", lambda: {"accepted": self.listener.accepted,
                                 "clients": len(self.reactor)})
        self._closed = False

    @property
    def name(self) -> str:
        """The rendezvous name clients connect to."""
        return self.listener.name

    def _prepare(self, conn, lease) -> Optional[dict]:
        """Reactor thread: turn one drained request lease into a
        dispatcher submit item (or handle it right here: shutdown
        messages and malformed requests never reach the dispatcher).

        ``lease`` is a :class:`~repro.ipc.channel.RecvLease`; under the
        zero-copy datapath its ``tree["data"]`` is a view straight into
        the client's ring slot, and the *dispatcher* releases the lease
        once the payload has been gathered into a batch buffer (or the
        solo execution completed) — the reactor never copies it.
        """
        header = lease.header
        if header.get("shutdown"):
            lease.release()
            conn.done()     # settle accounting; reaped once its flag is seen
            return None
        job_id = header.get("job_id", -1)
        op, mode = header.get("op"), header.get("mode", "sync")
        tree = lease.tree
        rid = lease.rid
        t_arr = time.perf_counter()
        req_nbytes = 0              # rebound below once data is extracted

        def reply(_jid: int, out) -> None:
            hdr = ({"job_id": job_id, _trace.RID_KEY: rid} if rid
                   else {"job_id": job_id})
            try:
                if isinstance(out, Exception):
                    hdr["error"] = f"{type(out).__name__}: {out}"
                    conn.reply({}, hdr, timeout_s=self.reply_timeout_s)
                else:
                    hdr["error"] = None
                    conn.reply({"result": np.asarray(out)}, hdr,
                               timeout_s=self.reply_timeout_s)
            finally:
                # SLO clock: reactor delivery -> reply sent (service time)
                self.slo.observe(time.perf_counter() - t_arr, req_nbytes)

        try:
            data = tree["data"] if isinstance(tree, dict) else None
            req_nbytes = int(getattr(data, "nbytes", 0) or 0)
            return {"op": op, "data": data,
                    "mode": ExecutionMode(mode),   # validated HERE, not
                    "on_complete": reply,          # mid-batch in submit_many
                    "rid": rid,
                    "lease": lease if lease.held else None}
        except Exception as e:
            # malformed request (missing data, bad mode string, ...): tell
            # the client instead of letting it time out.  reply() settles
            # the connection accounting in its finally, so swallow any
            # send failure here rather than re-settling in the reactor.
            lease.release()
            try:
                reply(job_id, e)
            except Exception:
                pass
            return None

    def _on_messages(self, conn, leases) -> None:
        """Reactor thread: feed one drained batch — e.g. a client's whole
        coalesced frame — into the dispatcher as one ``submit_many``, so
        K wire-microbatched requests enter the batching window together."""
        items = [it for it in (self._prepare(conn, lease)
                               for lease in leases) if it is not None]
        if items:
            self.dispatcher.submit_many(items)

    def start(self) -> "ServingFabric":
        """Begin accepting and serving (both in daemon threads)."""
        self.reactor.start()
        self.listener.start()
        return self

    def stats(self) -> dict:
        """Fabric-level counters: listener, reactor, per-client (including
        each connection's full transport stats — channel, rings, heap,
        governor), dispatcher, and the request SLO snapshot.  The
        ``metrics`` key is the same data as one flat dot-keyed dict (the
        :class:`~repro.obs.metrics.MetricsRegistry` view)."""
        return {
            "accepted": self.listener.accepted,
            "reactor": vars(self.reactor.stats),
            "clients": {c.cid: {"received": c.received, "replied": c.replied,
                                "inflight": c.inflight,
                                "transport": c.transport.stats()}
                        for c in self.reactor.connections()},
            "dispatcher": vars(self.dispatcher.stats),
            "slo": self.slo.snapshot(),
            "metrics": self.metrics.snapshot(),
        }

    def close(self) -> None:
        """Tear down in dependency order; safe to call twice."""
        if self._closed:
            return
        self._closed = True
        self.listener.close()               # no new clients
        for conn in self.reactor.connections():
            conn.transport.announce_close()  # unblock client-side waits
        self.reactor.close()                # stop sweeps, close transports
        if self._own_dispatcher:
            self.dispatcher.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class RemoteDispatcherClient:
    """Client-process side: the paper's request/query API over the wire."""

    def __init__(self, transport: ShmTransport,
                 policy: Optional[OffloadPolicy] = None,
                 latency: Optional[LatencyModel] = None,
                 own_transport: bool = False):
        self.transport = transport
        self.policy = policy or transport.policy
        self.latency = latency or transport.latency
        self.queries = QueryHandler(self.latency, self.policy)
        self._own_transport = own_transport
        self._ids = iter(range(1, 1 << 62))
        self._rids: dict[int, int] = {}    # job_id -> trace request id
        self._lock = threading.Lock()
        self._recv_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    @classmethod
    def connect(cls, listener_name: str,
                policy: Optional[OffloadPolicy] = None,
                latency: Optional[LatencyModel] = None,
                timeout_s: float = 30.0) -> "RemoteDispatcherClient":
        """Register with a :class:`ServingFabric` by rendezvous name and
        return a ready client owning its dedicated transport."""
        from repro.ipc.listener import connect as fabric_connect
        transport = fabric_connect(listener_name, policy=policy,
                                   latency=latency, timeout_s=timeout_s)
        return cls(transport, policy=policy, latency=latency,
                   own_transport=True)

    def _ensure_receiver(self) -> None:
        with self._lock:
            if self._recv_thread is None:
                self._recv_thread = threading.Thread(
                    target=self._recv_loop, daemon=True,
                    name="rocket-ipc-cli")
                self._recv_thread.start()

    def _recv_loop(self) -> None:
        while not self._stop.is_set():
            try:
                tree, header = self.transport.recv(timeout_s=0.05)
            except TimeoutError:
                continue
            except ChannelClosed:
                break
            err = header.get("error")
            result = RuntimeError(err) if err else tree["result"]
            if _trace.TRACE.enabled:
                rid = header.get(_trace.RID_KEY, 0)
                if isinstance(rid, int) and rid:
                    _trace.instant(_trace.CLIENT_RECV, rid=rid)
            self.queries.complete(header["job_id"], result)

    def request(self, op: str, data: np.ndarray,
                mode: ExecutionMode | str | None = None):
        """Paper Listing 1: sync returns the result, async/pipelined a
        job id for :meth:`query`."""
        mode = ExecutionMode(mode) if mode is not None else self.policy.mode
        with self._lock:
            job_id = next(self._ids)
        data = np.asarray(data)
        header = {"job_id": job_id, "op": op, "mode": mode.value}
        rid = 0
        if _trace.TRACE.enabled:
            # mint the request id HERE — the whole lifecycle (wire, reactor,
            # dispatcher, handler, reply) joins on it across processes
            rid = _trace.mint_rid()
            header[_trace.RID_KEY] = rid
            self._rids[job_id] = rid
        # all modes go through the receiver thread + QueryHandler: replies
        # are matched by job_id, so concurrent client threads can't steal
        # each other's results off the SPSC rx ring
        self._ensure_receiver()
        self.queries.register(Request(job_id, op, None, mode,
                                      nbytes=int(data.nbytes)))
        t0 = _trace.now() if rid else 0
        self.transport.send({"data": data}, header=header, mode=mode)
        if rid:
            _trace.emit(_trace.CLIENT_SEND, t0, rid=rid,
                        arg=min(int(data.nbytes), 0xFFFFFFFF))
        if mode == ExecutionMode.SYNC:
            return self.query(job_id)
        return job_id

    def query(self, job_id: int, timeout: float = 60.0):
        """Hybrid-polling wait for one job's result (raises server errors).

        Publishes any open coalesced frame first: a request still sitting
        in one must reach the wire before we block on its reply.  (Only
        the frame — a full ``flush()`` would block on, and re-raise the
        failures of, unrelated in-flight sends from other threads.)
        """
        self.transport.data.flush_open_frame()
        if not _trace.TRACE.enabled:
            out = self.queries.query(job_id, timeout)
        else:
            rid = self._rids.pop(job_id, 0)
            with _trace.span(_trace.QUERY_WAIT, rid=rid):
                out = self.queries.query(job_id, timeout)
        if isinstance(out, Exception):
            raise out
        return out

    def close(self) -> None:
        """Stop the receiver, tell the server we're leaving, and (when the
        client owns its transport, i.e. it came from :meth:`connect`) close
        it — the server reaps the connection and unlinks the arena."""
        self._stop.set()
        if self._recv_thread is not None:
            self._recv_thread.join(timeout=5)
        try:
            self.transport.send({}, header={"job_id": -1, "shutdown": True},
                                mode="sync", timeout_s=2.0)
        except (TimeoutError, ChannelClosed, ValueError):
            pass
        if self._own_transport:
            self.transport.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
