"""Process runners over the shm transport: batch producers + RPC bridge.

Two roles:

- :func:`start_producer` spawns a **producer process** that attaches to a
  transport by name and streams source batches through the data channel —
  the real-IPC version of the input pipeline's producer side.  The control
  channel carries ``seek`` / ``stop`` commands back to the producer
  (checkpoint-restore and shutdown), and the producer marks end-of-stream
  with an ``eof`` header.

- :class:`DispatcherServer` / :class:`RemoteDispatcherClient` bridge the
  in-process :class:`~repro.core.dispatcher.RequestDispatcher` across the
  transport, so clients in *other processes* issue
  ``request(op, data, mode)`` / ``query(job_id)`` exactly like the paper's
  Listing 1 — sync blocks for the result, async/pipelined return a job id
  completed by hybrid polling (reusing :class:`QueryHandler`).

Producer entry points are module-level functions (spawn-safe).
"""
from __future__ import annotations

import importlib
import multiprocessing as mp
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.dispatcher import QueryHandler, Request, RequestDispatcher
from repro.core.latency import LatencyModel
from repro.core.policy import ExecutionMode, OffloadPolicy
from repro.ipc.ring import ChannelClosed
from repro.ipc.transport import ShmTransport, TransportSpec


# ---------------------------------------------------------------------------
# source construction inside the producer process
# ---------------------------------------------------------------------------

def make_source_from_spec(spec: dict):
    """Build a batch source in the child from a picklable spec dict.

    kinds:
      ``synthetic_lm``  — repro.data.SyntheticLMSource(cfg, shape, seed, ...)
      ``factory``       — dotted ``module:function`` called with ``kwargs``
    """
    kind = spec.get("kind", "synthetic_lm")
    if kind == "synthetic_lm":
        from repro.data.pipeline import SyntheticLMSource
        return SyntheticLMSource(spec["cfg"], spec["shape"],
                                 seed=spec.get("seed", 0),
                                 batch_override=spec.get("batch_override"))
    if kind == "factory":
        mod_name, fn_name = spec["path"].split(":")
        fn = getattr(importlib.import_module(mod_name), fn_name)
        return fn(**spec.get("kwargs", {}))
    raise ValueError(f"unknown source kind {kind!r}")


def _producer_entry(name: str, source_spec: dict, policy: OffloadPolicy,
                    n_batches: Optional[int]) -> None:
    """Child main: attach, stream batches, honor seek/stop commands."""
    transport = ShmTransport.attach(name, policy=policy)
    source = make_source_from_spec(source_spec)
    state = {"it": iter(source), "gen": 0}

    def apply_seek(cmd: dict) -> None:
        # gen: seek generation, lets the consumer discard stale in-flight
        # batches published before the restore
        source.restore({"seed": cmd.get("seed", source.seed),
                        "step": cmd["step"]})
        state["it"] = iter(source)
        state["gen"] = cmd.get("gen", state["gen"] + 1)
        transport.data.flush()

    try:
        while True:
            sent = 0
            while n_batches is None or sent < n_batches:
                cmd = transport.ctrl.try_recv_msg()
                if cmd is not None:
                    if cmd.get("cmd") == "stop":
                        return
                    if cmd.get("cmd") == "seek":
                        apply_seek(cmd)
                        continue
                step = getattr(source, "step", sent)
                batch = next(state["it"])
                # mode semantics come from the policy: sync publishes
                # inline, async/pipelined overlap production with the copy
                transport.send(batch, header={"step": step,
                                              "gen": state["gen"]})
                sent += 1
            transport.data.flush()
            transport.send({}, header={"eof": True, "gen": state["gen"]},
                           mode="sync")
            # linger: a late stop makes the consumer's close racefree, and a
            # late seek (restore on a finished stream) restarts production
            deadline = time.perf_counter() + 30.0
            resumed = False
            while time.perf_counter() < deadline:
                cmd = transport.ctrl.try_recv_msg()
                if cmd is not None:
                    if cmd.get("cmd") == "stop":
                        return
                    if cmd.get("cmd") == "seek":
                        apply_seek(cmd)
                        resumed = True
                        break
                time.sleep(0.005)
            if not resumed:
                return
    except ChannelClosed:
        pass
    finally:
        transport.close()


@dataclass
class ProducerHandle:
    """Consumer-side handle on a spawned producer process."""
    transport: ShmTransport
    process: mp.process.BaseProcess
    gen: int = 0                 # current seek generation (0 = initial stream)

    def recv_batch(self, timeout_s: float = 60.0):
        """Next (batch, header); header["eof"] marks end of stream."""
        return self.transport.recv(timeout_s=timeout_s)

    def seek(self, step: int, seed: Optional[int] = None) -> int:
        """Reposition the producer; returns the new generation.  Batches
        already in flight carry the old generation — discard headers whose
        ``gen`` differs (stale data, possibly from a different seed)."""
        self.gen += 1
        msg = {"cmd": "seek", "step": step, "gen": self.gen}
        if seed is not None:
            msg["seed"] = seed
        self.transport.send_msg(msg)
        return self.gen

    def stop(self, timeout_s: float = 10.0) -> None:
        try:
            if self.process.is_alive():
                self.transport.send_msg({"cmd": "stop"}, timeout_s=2.0)
        except (TimeoutError, ChannelClosed, ValueError):
            pass
        # raise our closed flag first: a producer blocked on a full ring
        # sees ChannelClosed instead of waiting out its acquire timeout
        self.transport.announce_close()
        self.process.join(timeout=timeout_s)
        if self.process.is_alive():
            self.process.terminate()
            self.process.join(timeout=5)
        self.transport.close()


def start_producer(source_spec: dict,
                   policy: Optional[OffloadPolicy] = None,
                   spec: TransportSpec = TransportSpec(),
                   n_batches: Optional[int] = None,
                   name: Optional[str] = None,
                   ctx: Optional[mp.context.BaseContext] = None
                   ) -> ProducerHandle:
    """Create a transport and spawn a producer process streaming into it."""
    policy = policy or OffloadPolicy()
    transport = ShmTransport.create(name, spec, policy)
    ctx = ctx or mp.get_context("spawn")
    proc = ctx.Process(target=_producer_entry,
                       args=(transport.name, source_spec, policy, n_batches),
                       daemon=True)
    proc.start()
    return ProducerHandle(transport, proc)


# ---------------------------------------------------------------------------
# cross-process dispatcher bridge (paper Listing 1 across a real boundary)
# ---------------------------------------------------------------------------

class DispatcherServer:
    """Serves a :class:`RequestDispatcher`'s handlers to a remote client."""

    def __init__(self, dispatcher: RequestDispatcher,
                 transport: ShmTransport, workers: int = 2):
        self.dispatcher = dispatcher
        self.transport = transport
        self._pool = ThreadPoolExecutor(max_workers=workers,
                                        thread_name_prefix="rocket-ipc-srv")
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _reply(self, job_id: int, result, error: Optional[str]) -> None:
        tree = {} if error is not None else {"result": np.asarray(result)}
        self.transport.send(tree, header={"job_id": job_id, "error": error},
                            mode="sync")

    def _handle(self, header: dict, tree) -> None:
        job_id, op = header["job_id"], header["op"]
        mode = ExecutionMode(header.get("mode", "sync"))
        try:
            # route through the dispatcher so batching/stats apply; sync here
            # is fine — concurrency comes from the server pool
            if mode == ExecutionMode.SYNC:
                result = self.dispatcher.request(op, tree["data"], mode="sync")
            else:
                jid = self.dispatcher.request(op, tree["data"], mode=mode)
                result = self.dispatcher.query(jid)
            self._reply(job_id, result, None)
        except Exception as e:                      # surfaced client-side
            self._reply(job_id, None, f"{type(e).__name__}: {e}")

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                tree, header = self.transport.recv(timeout_s=0.05)
            except TimeoutError:
                continue
            except ChannelClosed:
                break
            if header.get("shutdown"):
                break
            self._pool.submit(self._handle, header, tree)

    def serve_forever(self) -> None:
        self._loop()

    def start(self) -> "DispatcherServer":
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="rocket-ipc-serve")
        self._thread.start()
        return self

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
        self._pool.shutdown(wait=True)


class RemoteDispatcherClient:
    """Client-process side: the paper's request/query API over the wire."""

    def __init__(self, transport: ShmTransport,
                 policy: Optional[OffloadPolicy] = None,
                 latency: Optional[LatencyModel] = None):
        self.transport = transport
        self.policy = policy or transport.policy
        self.latency = latency or transport.latency
        self.queries = QueryHandler(self.latency, self.policy)
        self._ids = iter(range(1, 1 << 62))
        self._lock = threading.Lock()
        self._recv_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def _ensure_receiver(self) -> None:
        with self._lock:
            if self._recv_thread is None:
                self._recv_thread = threading.Thread(
                    target=self._recv_loop, daemon=True,
                    name="rocket-ipc-cli")
                self._recv_thread.start()

    def _recv_loop(self) -> None:
        while not self._stop.is_set():
            try:
                tree, header = self.transport.recv(timeout_s=0.05)
            except TimeoutError:
                continue
            except ChannelClosed:
                break
            err = header.get("error")
            result = RuntimeError(err) if err else tree["result"]
            self.queries.complete(header["job_id"], result)

    def request(self, op: str, data: np.ndarray,
                mode: ExecutionMode | str | None = None):
        mode = ExecutionMode(mode) if mode is not None else self.policy.mode
        with self._lock:
            job_id = next(self._ids)
        data = np.asarray(data)
        header = {"job_id": job_id, "op": op, "mode": mode.value}
        # all modes go through the receiver thread + QueryHandler: replies
        # are matched by job_id, so concurrent client threads can't steal
        # each other's results off the SPSC rx ring
        self._ensure_receiver()
        self.queries.register(Request(job_id, op, None, mode,
                                      nbytes=int(data.nbytes)))
        self.transport.send({"data": data}, header=header, mode=mode)
        if mode == ExecutionMode.SYNC:
            return self.query(job_id)
        return job_id

    def query(self, job_id: int, timeout: float = 60.0):
        out = self.queries.query(job_id, timeout)
        if isinstance(out, Exception):
            raise out
        return out

    def close(self) -> None:
        self._stop.set()
        if self._recv_thread is not None:
            self._recv_thread.join(timeout=5)
        try:
            self.transport.send({}, header={"job_id": -1, "shutdown": True},
                                mode="sync", timeout_s=2.0)
        except (TimeoutError, ChannelClosed, ValueError):
            pass
