"""Process runners over the shm transport: batch producers + RPC bridge.

Two roles:

- :func:`start_producer` spawns a **producer process** that attaches to a
  transport by name and streams source batches through the data channel —
  the real-IPC version of the input pipeline's producer side.  The control
  channel carries ``seek`` / ``stop`` commands back to the producer
  (checkpoint-restore and shutdown), and the producer marks end-of-stream
  with an ``eof`` header.

- :class:`DispatcherServer` / :class:`RemoteDispatcherClient` bridge the
  in-process :class:`~repro.core.dispatcher.RequestDispatcher` across the
  transport, so clients in *other processes* issue
  ``request(op, data, mode)`` / ``query(job_id)`` exactly like the paper's
  Listing 1 — sync blocks for the result, async/pipelined return a job id
  completed by hybrid polling (reusing :class:`QueryHandler`).

- :class:`ServingFabric` is the multi-client generalization: a listener
  accepts any number of clients, a reactor multiplexes their transports in
  one thread, and pipelined requests from *different processes* are packed
  into single dispatcher batches (cross-client batch formation), replies
  demultiplexed by completion callback.  Clients reach it with
  :meth:`RemoteDispatcherClient.connect`.

Producer entry points are module-level functions (spawn-safe).
"""
from __future__ import annotations

import importlib
import multiprocessing as mp
import os
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.dispatcher import (DeadlineExceeded, QueryHandler, Request,
                                   RequestDispatcher)
from repro.core.latency import LatencyModel
from repro.core.policy import ExecutionMode, OffloadPolicy
from repro.ft import inject as _inject
from repro.ft.monitor import SLOMonitor
from repro.ipc.channel import DEADLINE_KEY, DEDUP_KEY, PRIO_KEY
from repro.ipc.ring import ChannelClosed
from repro.ipc.transport import ShmTransport, TransportSpec
from repro.obs import hwcounters as _hw
from repro.obs import trace as _trace
from repro.obs.metrics import MetricsRegistry, SLOTracker


# ---------------------------------------------------------------------------
# source construction inside the producer process
# ---------------------------------------------------------------------------

def make_source_from_spec(spec: dict):
    """Build a batch source in the child from a picklable spec dict.

    kinds:
      ``synthetic_lm``  — repro.data.SyntheticLMSource(cfg, shape, seed, ...)
      ``factory``       — dotted ``module:function`` called with ``kwargs``
    """
    kind = spec.get("kind", "synthetic_lm")
    if kind == "synthetic_lm":
        from repro.data.pipeline import SyntheticLMSource
        return SyntheticLMSource(spec["cfg"], spec["shape"],
                                 seed=spec.get("seed", 0),
                                 batch_override=spec.get("batch_override"))
    if kind == "factory":
        mod_name, fn_name = spec["path"].split(":")
        fn = getattr(importlib.import_module(mod_name), fn_name)
        return fn(**spec.get("kwargs", {}))
    raise ValueError(f"unknown source kind {kind!r}")


def _producer_entry(name: str, source_spec: dict, policy: OffloadPolicy,
                    n_batches: Optional[int]) -> None:
    """Child main: attach, stream batches, honor seek/stop commands."""
    transport = ShmTransport.attach(name, policy=policy)
    source = make_source_from_spec(source_spec)
    state = {"it": iter(source), "gen": 0}

    def apply_seek(cmd: dict) -> None:
        # gen: seek generation, lets the consumer discard stale in-flight
        # batches published before the restore
        source.restore({"seed": cmd.get("seed", source.seed),
                        "step": cmd["step"]})
        state["it"] = iter(source)
        state["gen"] = cmd.get("gen", state["gen"] + 1)
        transport.data.flush()

    try:
        while True:
            sent = 0
            while n_batches is None or sent < n_batches:
                cmd = transport.ctrl.try_recv_msg()
                if cmd is not None:
                    if cmd.get("cmd") == "stop":
                        return
                    if cmd.get("cmd") == "seek":
                        apply_seek(cmd)
                        continue
                step = getattr(source, "step", sent)
                batch = next(state["it"])
                # mode semantics come from the policy: sync publishes
                # inline, async/pipelined overlap production with the copy
                transport.send(batch, header={"step": step,
                                              "gen": state["gen"]})
                sent += 1
            transport.data.flush()
            transport.send({}, header={"eof": True, "gen": state["gen"]},
                           mode="sync")
            # linger: a late stop makes the consumer's close racefree, and a
            # late seek (restore on a finished stream) restarts production
            deadline = time.perf_counter() + policy.retry.linger_timeout_s
            resumed = False
            while time.perf_counter() < deadline:
                cmd = transport.ctrl.try_recv_msg()
                if cmd is not None:
                    if cmd.get("cmd") == "stop":
                        return
                    if cmd.get("cmd") == "seek":
                        apply_seek(cmd)
                        resumed = True
                        break
                time.sleep(0.005)
            if not resumed:
                return
    except ChannelClosed:
        pass
    finally:
        transport.close()


@dataclass
class ProducerHandle:
    """Consumer-side handle on a spawned producer process."""
    transport: ShmTransport
    process: mp.process.BaseProcess
    gen: int = 0                 # current seek generation (0 = initial stream)

    def recv_batch(self, timeout_s: Optional[float] = None):
        """Next (batch, header); header["eof"] marks end of stream.
        Default timeout is ``policy.retry.query_timeout_s``."""
        if timeout_s is None:
            timeout_s = self.transport.policy.retry.query_timeout_s
        return self.transport.recv(timeout_s=timeout_s)

    def seek(self, step: int, seed: Optional[int] = None) -> int:
        """Reposition the producer; returns the new generation.  Batches
        already in flight carry the old generation — discard headers whose
        ``gen`` differs (stale data, possibly from a different seed)."""
        self.gen += 1
        msg = {"cmd": "seek", "step": step, "gen": self.gen}
        if seed is not None:
            msg["seed"] = seed
        self.transport.send_msg(msg)
        return self.gen

    def stop(self, timeout_s: Optional[float] = None) -> None:
        """Stop the producer (command, then closed-flag, then terminate).
        Default timeout is ``policy.retry.join_timeout_s``."""
        retry = self.transport.policy.retry
        if timeout_s is None:
            timeout_s = retry.join_timeout_s
        try:
            if self.process.is_alive():
                self.transport.send_msg(
                    {"cmd": "stop"}, timeout_s=retry.shutdown_send_timeout_s)
        except (TimeoutError, ChannelClosed, ValueError):
            pass
        # raise our closed flag first: a producer blocked on a full ring
        # sees ChannelClosed instead of waiting out its acquire timeout
        self.transport.announce_close()
        self.process.join(timeout=timeout_s)
        if self.process.is_alive():
            self.process.terminate()
            self.process.join(timeout=retry.join_timeout_s)
        self.transport.close()


def start_producer(source_spec: dict,
                   policy: Optional[OffloadPolicy] = None,
                   spec: TransportSpec = TransportSpec(),
                   n_batches: Optional[int] = None,
                   name: Optional[str] = None,
                   ctx: Optional[mp.context.BaseContext] = None
                   ) -> ProducerHandle:
    """Create a transport and spawn a producer process streaming into it."""
    policy = policy or OffloadPolicy()
    transport = ShmTransport.create(name, spec, policy)
    ctx = ctx or mp.get_context("spawn")
    proc = ctx.Process(target=_producer_entry,
                       args=(transport.name, source_spec, policy, n_batches),
                       daemon=True)
    proc.start()
    return ProducerHandle(transport, proc)


# ---------------------------------------------------------------------------
# cross-process dispatcher bridge (paper Listing 1 across a real boundary)
# ---------------------------------------------------------------------------

class DispatcherServer:
    """Serves a :class:`RequestDispatcher`'s handlers to a remote client."""

    def __init__(self, dispatcher: RequestDispatcher,
                 transport: ShmTransport, workers: int = 2):
        self.dispatcher = dispatcher
        self.transport = transport
        self._pool = ThreadPoolExecutor(max_workers=workers,
                                        thread_name_prefix="rocket-ipc-srv")
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _reply(self, job_id: int, result, error: Optional[str]) -> None:
        tree = {} if error is not None else {"result": np.asarray(result)}
        self.transport.send(tree, header={"job_id": job_id, "error": error},
                            mode="sync")

    def _handle(self, header: dict, tree) -> None:
        job_id, op = header["job_id"], header["op"]
        mode = ExecutionMode(header.get("mode", "sync"))
        try:
            # route through the dispatcher so batching/stats apply; sync here
            # is fine — concurrency comes from the server pool
            if mode == ExecutionMode.SYNC:
                result = self.dispatcher.request(op, tree["data"], mode="sync")
            else:
                jid = self.dispatcher.request(op, tree["data"], mode=mode)
                result = self.dispatcher.query(jid)
            self._reply(job_id, result, None)
        except Exception as e:                      # surfaced client-side
            self._reply(job_id, None, f"{type(e).__name__}: {e}")

    def _loop(self) -> None:
        poll_s = self.transport.policy.retry.recv_poll_s
        while not self._stop.is_set():
            try:
                tree, header = self.transport.recv(timeout_s=poll_s)
            except TimeoutError:
                continue
            except ChannelClosed:
                break
            if header.get("shutdown"):
                break
            self._pool.submit(self._handle, header, tree)

    def serve_forever(self) -> None:
        """Serve on the caller's thread until shutdown/close."""
        self._loop()

    def start(self) -> "DispatcherServer":
        """Serve from a background daemon thread."""
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="rocket-ipc-serve")
        self._thread.start()
        return self

    def close(self) -> None:
        """Stop the serve loop and drain the handler pool."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(
                timeout=self.transport.policy.retry.join_timeout_s)
        self._pool.shutdown(wait=True)


class ServingFabric:
    """Multi-client serving: listener + reactor shards + shared dispatcher.

    The paper's server generalized from one queue pair to N (§IV-C at
    fleet scale): a :class:`~repro.ipc.listener.Listener` accepts client
    registrations and mints each one a dedicated transport; ``reactors``
    :class:`~repro.ipc.reactor.Reactor` shards multiplex them (clients
    partitioned round-robin at accept time — one drain loop stops being
    the serving ceiling) with round-robin fairness inside each shard; and
    every drained request is fed to *one* shared
    :class:`RequestDispatcher`, so pipelined requests arriving from
    **different processes** inside the batching window are packed into a
    single handler call (cross-client batch formation) and the results are
    demultiplexed back to the right transports by completion callbacks.

    **SLO serving**: requests carrying the reserved priority/deadline
    header keys (:data:`~repro.ipc.channel.PRIO_KEY` /
    :data:`~repro.ipc.channel.DEADLINE_KEY` — set by
    :meth:`RemoteDispatcherClient.request`) are drained, batched, and
    executed in lane order; the dispatcher sheds requests its service
    model predicts past deadline (counted + immediate error reply), the
    per-lane :class:`~repro.obs.metrics.SLOTracker` records latency and
    misses, and a :class:`~repro.ft.monitor.SLOMonitor` watchdog
    evaluates rule bounds over the live metrics plane
    (``fabric.monitor.check()``).  ``default_deadline_ms`` applies a
    server-side deadline (from arrival) to requests that carry none.

    The large-message datapath is transparent here: a client request (or a
    server reply) at/over ``policy.heap_threshold_bytes`` rides the
    connection's bulk-heap extents instead of a ring slot, so request and
    reply sizes are bounded by heap geometry (``spec.heap_extents ×
    spec.heap_extent_bytes`` per direction), not by ``data_slot_bytes``.

    Teardown order matters and is owned by :meth:`close` (one ``with``
    block instead of a tuple of things to unwind): stop accepting, stop
    the sweep, flag every client, close transports, then the dispatcher.
    """

    def __init__(self, dispatcher: RequestDispatcher,
                 name: Optional[str] = None,
                 spec: TransportSpec = TransportSpec(),
                 policy: Optional[OffloadPolicy] = None,
                 latency: Optional[LatencyModel] = None,
                 max_clients: int = 64,
                 max_drain_per_sweep: int = 8,
                 max_inflight: int = 16,
                 reply_timeout_s: Optional[float] = None,
                 own_dispatcher: bool = False,
                 reactors: int = 1,
                 default_deadline_ms: Optional[float] = None):
        from repro.ipc.listener import Listener
        from repro.ipc.reactor import Reactor

        self.dispatcher = dispatcher
        self.policy = policy or dispatcher.policy
        self.reply_timeout_s = (reply_timeout_s if reply_timeout_s is not None
                                else self.policy.retry.reply_timeout_s)
        self._own_dispatcher = own_dispatcher
        # server-side deadline applied (from arrival time) to requests that
        # carry none of their own — 0 disables
        self.default_deadline_ns = int((default_deadline_ms or 0) * 1e6)
        # sharded reactors: N independent drain loops, clients partitioned
        # round-robin at accept time so one sweep thread stops being the
        # serving ceiling; shard 0 doubles as the legacy ``.reactor`` view
        self.reactors = [
            Reactor(self.policy, on_messages=self._on_messages,
                    max_drain_per_sweep=max_drain_per_sweep,
                    max_inflight=max_inflight)
            for _ in range(max(1, reactors))]
        self.reactor = self.reactors[0]
        self._accept_lock = threading.Lock()
        self._next_shard = 0
        self.listener = Listener(name, spec, self.policy, latency,
                                 max_clients=max_clients,
                                 on_accept=self._accept)
        # unified metrics plane: every stats surface in the fabric behind
        # one flat snapshot, plus the per-request SLO monitor (previously
        # orphaned ft/monitor.py + core/latency.py, now fed by replies)
        self.slo = SLOTracker(latency or getattr(dispatcher, "latency", None))
        self.metrics = MetricsRegistry()
        self.metrics.register("reactor", self._reactor_stats)
        self.metrics.register("dispatcher", lambda: self.dispatcher.stats)
        self.metrics.register("slo", self.slo)
        self.metrics.register(
            "listener", lambda: {"accepted": self.listener.accepted,
                                 "clients": sum(len(r)
                                                for r in self.reactors)})
        # live SLO watchdog over the metrics plane (ft/monitor.SLOMonitor):
        # rules read the same flat keys metrics.snapshot() exposes
        self.monitor = SLOMonitor(self.metrics)
        if self.default_deadline_ns:
            self.monitor.add_rule("slo.p95_ms",
                                  self.default_deadline_ns / 1e6)
        self.metrics.register("slo_monitor", self.monitor)
        # hardware-witness plane: per-phase counter totals (insn/byte,
        # LLC misses, ctx switches) land in the same flat snapshot under
        # hw.* when profiling is enabled; a child fabric spawned by a
        # profiling parent inherits enablement through the environment
        _hw.maybe_enable_from_env()
        self.metrics.register("hw", _hw.snapshot)
        self._closed = False

    @property
    def name(self) -> str:
        """The rendezvous name clients connect to."""
        return self.listener.name

    # -- sharding ---------------------------------------------------------------
    def _accept(self, transport: ShmTransport) -> None:
        """Accept-time partitioning: each new client lands on one reactor
        shard (round-robin — balanced under churn without rebalancing
        live connections, which would break the per-ring SPSC contract),
        its lane seeded from the registration hint so the very first
        sweep already drains it in lane order."""
        with self._accept_lock:
            shard = self._next_shard
            self._next_shard = (self._next_shard + 1) % len(self.reactors)
        conn = self.reactors[shard].add(transport)
        lane = (getattr(transport, "accept_meta", None) or {}).get("lane", 0)
        if isinstance(lane, int) and not isinstance(lane, bool):
            conn.lane = lane

    def _all_connections(self) -> list:
        """Live connections across every reactor shard."""
        return [c for r in self.reactors for c in r.connections()]

    def _reactor_stats(self) -> dict:
        """Reactor counters summed across shards (+ the shard count)."""
        agg: dict = {}
        for r in self.reactors:
            for k, v in vars(r.stats).items():
                agg[k] = agg.get(k, 0) + v
        agg["shards"] = len(self.reactors)
        return agg

    def _prepare(self, conn, lease) -> Optional[dict]:
        """Reactor thread: turn one drained request lease into a
        dispatcher submit item (or handle it right here: shutdown
        messages and malformed requests never reach the dispatcher).

        ``lease`` is a :class:`~repro.ipc.channel.RecvLease`; under the
        zero-copy datapath its ``tree["data"]`` is a view straight into
        the client's ring slot, and the *dispatcher* releases the lease
        once the payload has been gathered into a batch buffer (or the
        solo execution completed) — the reactor never copies it.
        """
        header = lease.header
        if header.get("shutdown"):
            lease.release()
            conn.done()     # settle accounting; reaped once its flag is seen
            return None
        job_id = header.get("job_id", -1)
        op, mode = header.get("op"), header.get("mode", "sync")
        # SLO wire meta: strip the reserved lane/deadline keys before the
        # header reaches any handler; a request without its own deadline
        # inherits the fabric default (clocked from arrival)
        priority = header.pop(PRIO_KEY, 0)
        if not isinstance(priority, int) or isinstance(priority, bool):
            priority = 0
        deadline_ns = header.pop(DEADLINE_KEY, 0)
        if not isinstance(deadline_ns, int) or isinstance(deadline_ns, bool):
            deadline_ns = 0
        if not deadline_ns and self.default_deadline_ns:
            deadline_ns = time.perf_counter_ns() + self.default_deadline_ns
        # idempotent request id (exactly-once replay after reconnect):
        # stripped here, fed to the dispatcher's dedup window
        dedup = header.pop(DEDUP_KEY, None)
        if not isinstance(dedup, int) or isinstance(dedup, bool):
            dedup = None
        tree = lease.tree
        rid = lease.rid
        t_arr = time.perf_counter()
        req_nbytes = 0              # rebound below once data is extracted

        def reply(_jid: int, out) -> None:
            hdr = ({"job_id": job_id, _trace.RID_KEY: rid} if rid
                   else {"job_id": job_id})
            try:
                if isinstance(out, Exception):
                    hdr["error"] = f"{type(out).__name__}: {out}"
                    conn.reply({}, hdr, timeout_s=self.reply_timeout_s)
                else:
                    hdr["error"] = None
                    conn.reply({"result": np.asarray(out)}, hdr,
                               timeout_s=self.reply_timeout_s)
            finally:
                # SLO clock: reactor delivery -> reply sent (service time);
                # a reply landing past the request's deadline is a counted
                # per-lane miss (distinct from a shed: the work ran, so a
                # shed error reply is never double-counted as a miss)
                miss = (not isinstance(out, DeadlineExceeded)
                        and bool(deadline_ns)
                        and time.perf_counter_ns() > deadline_ns)
                self.slo.observe(time.perf_counter() - t_arr, req_nbytes,
                                 lane=priority, miss=miss)

        try:
            data = tree["data"] if isinstance(tree, dict) else None
            req_nbytes = int(getattr(data, "nbytes", 0) or 0)
            return {"op": op, "data": data,
                    "mode": ExecutionMode(mode),   # validated HERE, not
                    "on_complete": reply,          # mid-batch in submit_many
                    "rid": rid, "dedup": dedup,
                    "priority": priority, "deadline_ns": deadline_ns,
                    "lease": lease if lease.held else None}
        except Exception as e:
            # malformed request (missing data, bad mode string, ...): tell
            # the client instead of letting it time out.  reply() settles
            # the connection accounting in its finally, so swallow any
            # send failure here rather than re-settling in the reactor.
            lease.release()
            try:
                reply(job_id, e)
            except Exception:
                pass
            return None

    def _on_messages(self, conn, leases) -> None:
        """Reactor thread: feed one drained batch — e.g. a client's whole
        coalesced frame — into the dispatcher as one ``submit_many``, so
        K wire-microbatched requests enter the batching window together."""
        if _inject._PLANE is not None:
            # replication pulls (__ckpt.* ops from a warm standby) drain
            # through this same path but must not advance the crash
            # schedule: the drill is indexed against the *serving* request
            # stream, and standby sync cadence would make it nondeterministic
            serving = any(
                not str(lease.header.get("op", "")).startswith("__ckpt.")
                for lease in leases)
            if serving and _inject.fire("worker.crash") is not None:
                # hard process death mid-batch — the chaos drill the
                # supervisor and reconnecting clients exist for (no
                # cleanup on purpose)
                os._exit(23)
        items = [it for it in (self._prepare(conn, lease)
                               for lease in leases) if it is not None]
        if items:
            self.dispatcher.submit_many(items)

    def start(self) -> "ServingFabric":
        """Begin accepting and serving (all in daemon threads)."""
        for r in self.reactors:
            r.start()
        self.listener.start()
        return self

    def stats(self) -> dict:
        """Fabric-level counters: listener, reactor (summed over shards),
        per-client (including each connection's full transport stats —
        channel, rings, heap, governor), dispatcher, and the request SLO
        snapshot.  The ``metrics`` key is the same data as one flat
        dot-keyed dict (the :class:`~repro.obs.metrics.MetricsRegistry`
        view).  With one shard client keys are the bare cids (the
        pre-sharding shape); with several they are ``"s<shard>c<cid>"``
        (cids are only unique within a shard)."""
        if len(self.reactors) == 1:
            clients = {c.cid: {"received": c.received, "replied": c.replied,
                               "inflight": c.inflight, "lane": c.lane,
                               "transport": c.transport.stats()}
                       for c in self.reactor.connections()}
        else:
            clients = {f"s{si}c{c.cid}": {
                           "received": c.received, "replied": c.replied,
                           "inflight": c.inflight, "lane": c.lane,
                           "transport": c.transport.stats()}
                       for si, r in enumerate(self.reactors)
                       for c in r.connections()}
        return {
            "accepted": self.listener.accepted,
            "reactor": self._reactor_stats(),
            "clients": clients,
            "dispatcher": vars(self.dispatcher.stats),
            "slo": self.slo.snapshot(),
            "metrics": self.metrics.snapshot(),
        }

    def close(self) -> None:
        """Tear down in dependency order; safe to call twice."""
        if self._closed:
            return
        self._closed = True
        self.listener.close()               # no new clients
        for conn in self._all_connections():
            conn.transport.announce_close()  # unblock client-side waits
        for r in self.reactors:
            r.close()                       # stop sweeps, close transports
        if self._own_dispatcher:
            self.dispatcher.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class ReconnectTimeout(ConnectionError, TimeoutError):
    """A :meth:`RemoteDispatcherClient.reconnect` ran out of a
    caller-imposed time budget (e.g. the enclosing query's deadline)
    before any attempt succeeded.  Distinct from the plain
    ``ConnectionError`` of exhausted *attempts* so callers can tell "the
    server never came back within my deadline" (a promotion or restart
    overran it) from "the server is gone"; subclasses both
    ``ConnectionError`` and ``TimeoutError`` so either family of
    handlers still fires."""


class RemoteDispatcherClient:
    """Client-process side: the paper's request/query API over the wire.

    **Crash recovery**: a client minted by :meth:`connect` is resilient
    to server death.  Every request carries an idempotent id
    (``(session_id << 32) | job_id`` under
    :data:`~repro.ipc.channel.DEDUP_KEY`) and is tracked as *unacked*
    until its reply lands; when the transport dies or the server's
    heartbeat goes stale, :meth:`reconnect` re-registers through the
    listener (bounded retries with exponential backoff —
    ``policy.retry``) and resubmits every unacked request.  The server's
    dedup window makes the replay exactly-once: re-executions are
    suppressed and duplicate replies are filtered here (counted in
    ``dup_replies``; requests whose reply never arrives at all are
    counted in ``lost_replies`` when their query finally times out).
    The receiver thread stamps the client-side heartbeat word so the
    server can tell a live-but-idle client from a dead one.
    """

    def __init__(self, transport: ShmTransport,
                 policy: Optional[OffloadPolicy] = None,
                 latency: Optional[LatencyModel] = None,
                 own_transport: bool = False):
        self.transport = transport
        self.policy = policy or transport.policy
        self.latency = latency or transport.latency
        self.queries = QueryHandler(self.latency, self.policy)
        self._own_transport = own_transport
        # a client process spawned by a profiling parent profiles too
        # (publish / governor / reply_drain phases), same env handshake
        # as the tracer's
        _hw.maybe_enable_from_env()
        self.lane = 0                      # default priority for request()
        # 32-bit session nonce: scopes idempotent ids to this client life
        self.session_id = int.from_bytes(os.urandom(4), "little") or 1
        self._ids = iter(range(1, 1 << 62))
        self._rids: dict[int, int] = {}    # job_id -> trace request id
        self._lock = threading.Lock()
        self._recv_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # reconnect-with-replay state (populated by connect())
        self._listener_name: Optional[str] = None
        self._latency_arg = latency
        self._policy_arg = policy
        self._reconnect_lock = threading.Lock()
        # serializes receiver-thread transport use against the reconnect
        # swap: closing an arena out from under a blocked recv would tear
        # live memoryviews (BufferError) instead of failing cleanly
        self._transport_lock = threading.RLock()
        self._unacked: dict[int, tuple[dict, np.ndarray]] = {}
        self._completed: set[int] = set()
        self._completed_q: deque = deque()
        self._completed_cap = 4 * self.policy.retry.dedup_window
        self.reconnects = 0
        self.retries = 0
        self.dup_replies = 0
        self.lost_replies = 0

    @classmethod
    def connect(cls, listener_name: str,
                policy: Optional[OffloadPolicy] = None,
                latency: Optional[LatencyModel] = None,
                timeout_s: Optional[float] = None,
                lane: int = 0) -> "RemoteDispatcherClient":
        """Register with a :class:`ServingFabric` by rendezvous name and
        return a ready client owning its dedicated transport.  ``lane``
        hints the client's priority class at accept time (the server
        seeds its connection's drain lane before the first request) and
        becomes the default ``priority`` for :meth:`request`.  Default
        ``timeout_s`` is ``policy.retry.connect_timeout_s``."""
        from repro.ipc.listener import connect as fabric_connect
        if timeout_s is None:
            timeout_s = (policy or OffloadPolicy()).retry.connect_timeout_s
        transport = fabric_connect(listener_name, policy=policy,
                                   latency=latency, timeout_s=timeout_s,
                                   meta={"lane": lane} if lane else None)
        client = cls(transport, policy=policy, latency=latency,
                     own_transport=True)
        client.lane = lane
        client._listener_name = listener_name
        return client

    def _ensure_receiver(self) -> None:
        with self._lock:
            if self._recv_thread is None:
                self._recv_thread = threading.Thread(
                    target=self._recv_loop, daemon=True,
                    name="rocket-ipc-cli")
                self._recv_thread.start()

    def _recv_loop(self) -> None:
        poll_s = self.policy.retry.recv_poll_s
        while not self._stop.is_set():
            failed = False
            with self._transport_lock:
                transport = self.transport
                # reply_drain scope: only drains that actually yield a
                # reply are accounted (a timed-out idle poll is sleep,
                # not drain cost — metering it would swamp the profile)
                c0 = _hw.begin() if _hw.PROF.enabled else None
                try:
                    transport.heartbeat()  # liveness stamp (rate-limited)
                    tree, header = transport.recv(timeout_s=poll_s)
                except TimeoutError:
                    continue
                except Exception:
                    # transport torn down (server death / reconnect swap)
                    failed = True
            if failed:
                # idle until reconnect() installs a fresh transport or we
                # stop — only a reconnectable client keeps the thread alive
                if self._listener_name is None:
                    break
                time.sleep(poll_s)
                continue
            err = header.get("error")
            result = RuntimeError(err) if err else tree["result"]
            rid = header.get(_trace.RID_KEY, 0)
            rid = rid if isinstance(rid, int) else 0
            if _trace.TRACE.enabled and rid:
                _trace.instant(_trace.CLIENT_RECV, rid=rid)
            if c0 is not None:
                _hw.end(c0, "reply_drain", rid=rid,
                        nbytes=getattr(result, "nbytes", 0))
            job_id = header["job_id"]
            with self._lock:
                if job_id in self._completed:
                    # replayed request answered twice (original completed
                    # after the resubmit raced it) — exactly-once delivery
                    # means dropping it here, counted
                    self.dup_replies += 1
                    continue
                self._completed.add(job_id)
                self._completed_q.append(job_id)
                while len(self._completed_q) > self._completed_cap:
                    self._completed.discard(self._completed_q.popleft())
                self._unacked.pop(job_id, None)
            self.queries.complete(job_id, result)

    # -- crash recovery -------------------------------------------------------
    def reconnect(self, deadline: Optional[float] = None) -> None:
        """Re-register through the listener and replay unacked requests.

        Bounded attempts (``policy.retry.max_reconnects``) with
        exponential backoff between them; the old transport is closed
        (its arena unlinks once the server reaps it) and every request
        still awaiting a reply is resubmitted with its original
        idempotent id — the server's dedup window turns the replay into
        exactly-once execution.  Raises ``ConnectionError`` when every
        attempt fails; only clients from :meth:`connect` can reconnect.

        ``deadline`` (absolute ``time.perf_counter()``) bounds the
        *cumulative* time spent here: each attempt's connect timeout and
        each backoff sleep are clipped to the remaining budget, and
        exhausting it raises :class:`ReconnectTimeout` — so a recovery
        (e.g. a standby promotion) that overruns the enclosing query's
        deadline surfaces as a typed error instead of over-waiting.
        """
        if self._listener_name is None:
            raise ConnectionError("client has no listener to reconnect to")
        from repro.ipc.listener import connect as fabric_connect
        retry = self.policy.retry

        def remaining_or_raise(last: Optional[Exception]) -> Optional[float]:
            if deadline is None:
                return None
            left = deadline - time.perf_counter()
            if left <= 0:
                raise ReconnectTimeout(
                    f"reconnect to {self._listener_name!r} exceeded its "
                    f"deadline budget") from last
            return left

        with self._reconnect_lock:
            last: Optional[Exception] = None
            for attempt in range(max(1, retry.max_reconnects)):
                left = remaining_or_raise(last)
                timeout_s = (retry.connect_timeout_s if left is None
                             else min(retry.connect_timeout_s, left))
                try:
                    transport = fabric_connect(
                        self._listener_name, policy=self._policy_arg,
                        latency=self._latency_arg,
                        timeout_s=timeout_s,
                        meta={"lane": self.lane} if self.lane else None)
                except Exception as e:
                    last = e
                    left = remaining_or_raise(last)
                    backoff = retry.backoff_s(attempt)
                    time.sleep(backoff if left is None
                               else min(backoff, left))
                    continue
                with self._transport_lock:
                    # swap under the receiver's lock: close must not tear
                    # views out from under a blocked recv
                    old, self.transport = self.transport, transport
                    try:
                        old.close()
                    except Exception:
                        pass
                self.reconnects += 1
                self._resubmit_unacked()
                return
            raise ConnectionError(
                f"reconnect to {self._listener_name!r} failed after "
                f"{retry.max_reconnects} attempts") from last

    def _resubmit_unacked(self) -> None:
        """Replay every request still awaiting a reply, oldest first, on
        the (fresh) transport — same headers, same idempotent ids."""
        with self._lock:
            pending = sorted(self._unacked.items())
        for _job_id, (header, data) in pending:
            self.transport.send({"data": data}, header=dict(header),
                                mode="sync")

    def request(self, op: str, data: np.ndarray,
                mode: ExecutionMode | str | None = None,
                priority: Optional[int] = None,
                deadline_ms: Optional[float] = None):
        """Paper Listing 1: sync returns the result, async/pipelined a
        job id for :meth:`query`.

        ``priority`` selects the request's SLO lane (0 = highest; default
        is the client's ``lane``), ``deadline_ms`` a relative deadline
        stamped as an absolute CLOCK_MONOTONIC wire deadline — both ride
        the META_BINARY header (reserved int tags, no pickle).  A request
        the server sheds or fails comes back as a ``RuntimeError`` whose
        message starts with ``DeadlineExceeded`` from :meth:`query`.
        """
        mode = ExecutionMode(mode) if mode is not None else self.policy.mode
        with self._lock:
            job_id = next(self._ids)
        data = np.asarray(data)
        header = {"job_id": job_id, "op": op, "mode": mode.value,
                  # idempotent request id: lets the server suppress
                  # re-execution when this request is replayed after a
                  # reconnect (session-scoped, so restarts never collide)
                  DEDUP_KEY: (self.session_id << 32)
                  | (job_id & 0xFFFFFFFF)}
        priority = self.lane if priority is None else int(priority)
        if priority:
            header[PRIO_KEY] = priority
        if deadline_ms is not None:
            header[DEADLINE_KEY] = (time.perf_counter_ns()
                                    + int(deadline_ms * 1e6))
        rid = 0
        if _trace.TRACE.enabled:
            # mint the request id HERE — the whole lifecycle (wire, reactor,
            # dispatcher, handler, reply) joins on it across processes
            rid = _trace.mint_rid()
            header[_trace.RID_KEY] = rid
            self._rids[job_id] = rid
        # all modes go through the receiver thread + QueryHandler: replies
        # are matched by job_id, so concurrent client threads can't steal
        # each other's results off the SPSC rx ring
        self._ensure_receiver()
        self.queries.register(Request(job_id, op, None, mode,
                                      nbytes=int(data.nbytes)))
        # track as unacked BEFORE the send: if the transport dies inside
        # send(), the reconnect replay below already covers this request
        with self._lock:
            self._unacked[job_id] = (header, data)
        t0 = _trace.now() if rid else 0
        try:
            self.transport.send({"data": data}, header=header, mode=mode)
            self.transport.heartbeat()
        except (ChannelClosed, TimeoutError, ValueError, OSError):
            if self._listener_name is None:
                raise
            self.reconnect()       # resubmits unacked, this request included
        if rid:
            _trace.emit(_trace.CLIENT_SEND, t0, rid=rid,
                        arg=min(int(data.nbytes), 0xFFFFFFFF))
        if mode == ExecutionMode.SYNC:
            return self.query(job_id)
        return job_id

    def query(self, job_id: int, timeout: Optional[float] = None):
        """Hybrid-polling wait for one job's result (raises server errors).

        Publishes any open coalesced frame first: a request still sitting
        in one must reach the wire before we block on its reply.  (Only
        the frame — a full ``flush()`` would block on, and re-raise the
        failures of, unrelated in-flight sends from other threads.)

        Default timeout is ``policy.retry.query_timeout_s``.  A client
        from :meth:`connect` waits in heartbeat-sized slices: when the
        server's heartbeat goes stale mid-wait it reconnects and replays
        before resuming the wait, so one server crash costs recovery
        time, not the whole query timeout.  A reply that never arrives
        even so is counted in ``lost_replies``.
        """
        if timeout is None:
            timeout = self.policy.retry.query_timeout_s
        try:
            self.transport.data.flush_open_frame()
        except (ChannelClosed, ValueError, OSError):
            if self._listener_name is None:
                raise
            self.reconnect()
        rid = self._rids.pop(job_id, 0) if _trace.TRACE.enabled else 0
        span = _trace.span(_trace.QUERY_WAIT, rid=rid) if rid else None
        if span is not None:
            span.__enter__()
        try:
            deadline = time.perf_counter() + timeout
            retry = self.policy.retry
            # wait in heartbeat-interval slices (not stale_s slices): the
            # staleness check below only runs at slice boundaries, so a
            # coarser slice would quantize failure detection to up to
            # 2x stale_s depending on heartbeat phase at the crash
            slice_s = max(retry.heartbeat_interval_s, 0.05)
            resubmits = 0
            # single-request resubmit patience: a slice is too short to
            # conclude a reply was dropped (it may simply be in flight),
            # so re-send only after a full stale window of silence
            last_send = time.perf_counter()
            while True:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    with self._lock:
                        lost = self._unacked.pop(job_id, None) is not None
                    if lost:
                        self.lost_replies += 1
                    raise TimeoutError(f"job {job_id} timed out")
                try:
                    out = self.queries.query(job_id,
                                             min(remaining, slice_s))
                    break
                except TimeoutError:
                    # mid-wait failure detection: a stale server heartbeat
                    # (or dead transport) triggers reconnect + replay here
                    # rather than burning the rest of the timeout
                    if self._listener_name is None:
                        continue
                    try:
                        stale = self.transport.peer_stale()
                    except Exception:
                        stale = True       # transport already torn down
                    if stale:
                        try:
                            # bound the cumulative reconnect wait by this
                            # query's own deadline: a promotion/restart
                            # that overruns it becomes a typed error now,
                            # not a silent over-wait
                            self.reconnect(deadline=deadline)
                        except ReconnectTimeout:
                            with self._lock:
                                lost = (self._unacked.pop(job_id, None)
                                        is not None)
                            if lost:
                                self.lost_replies += 1
                            raise
                        except ConnectionError:
                            pass
                        last_send = time.perf_counter()  # replay counts
                        continue
                    # server alive but this request never answered — the
                    # request (or its reply) was dropped in transit (e.g.
                    # quarantined as corrupt).  Bounded single-request
                    # resubmit, idempotent by dedup id, and only after a
                    # full stale window of silence since the last send —
                    # one elapsed slice just means the reply is in flight.
                    if (time.perf_counter() - last_send
                            < retry.heartbeat_stale_s):
                        continue
                    with self._lock:
                        entry = self._unacked.get(job_id)
                    if entry is not None \
                            and resubmits < retry.max_reconnects:
                        hdr, payload = entry
                        try:
                            self.transport.send({"data": payload},
                                                header=dict(hdr),
                                                mode="sync")
                        except Exception:
                            continue
                        resubmits += 1
                        self.retries += 1
                        last_send = time.perf_counter()
        finally:
            if span is not None:
                span.__exit__(None, None, None)
        if isinstance(out, Exception):
            raise out
        return out

    def close(self) -> None:
        """Stop the receiver, tell the server we're leaving, and (when the
        client owns its transport, i.e. it came from :meth:`connect`) close
        it — the server reaps the connection and unlinks the arena."""
        retry = self.policy.retry
        self._stop.set()
        if self._recv_thread is not None:
            self._recv_thread.join(timeout=retry.join_timeout_s)
        try:
            self.transport.send({}, header={"job_id": -1, "shutdown": True},
                                mode="sync",
                                timeout_s=retry.shutdown_send_timeout_s)
        except (TimeoutError, ChannelClosed, ValueError):
            pass
        if self._own_transport:
            self.transport.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
