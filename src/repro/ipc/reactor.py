"""Reactor: one server thread multiplexing N client transports fairly.

The serving side of the multi-client fabric.  One thread sweeps all
registered connections round-robin, draining at most
``max_drain_per_sweep`` messages from each per pass, so a chatty client
cannot monopolize the sweep; a per-connection ``max_inflight`` admission
cap stops a flooding client from stuffing the shared dispatcher queue —
once its replies lag, its requests stay in its *own* ring and the ring's
bounded depth backpressures the sender (the paper's bounded queue pairs,
now doing double duty as a fairness mechanism).  Replies — which run on
the shared dispatcher worker — use a short timeout, and a timed-out or
closed reply path marks the connection dead for reaping, so a vanished
client costs one bounded stall rather than a 30s head-of-line block per
outstanding reply.

**Batched drain**: each poll iteration pulls *all* ready messages from a
connection in one ``try_recv_many`` sweep (bounded by the fairness
quantum and the admission cap) — a client's coalesced frame of K
sub-messages costs one ring poll and one ``on_messages`` handoff into
batch formation, not K callback iterations.

**Lane-ordered sweep** (SLO serving): each connection remembers the most
urgent priority class its last drain saw (the wire's reserved
:data:`~repro.ipc.channel.PRIO_KEY` header), and every sweep visits
connections sorted ``(lane, cid)`` — a priority-0 client's ring is
drained before best-effort lanes under the same per-connection quantum,
so lane ordering holds end to end (wire → drain → dispatcher heap)
without starving anyone: the quantum and admission caps are unchanged.

**Zero-copy drain** (default, ``policy.zero_copy_serving``): requests are
received as :class:`~repro.ipc.channel.RecvLease` views into the shared
slot — no receive-side staging copy — and handed to ``on_message`` still
leased; the consumer (the fabric → dispatcher) releases each lease once
the payload has been gathered into a batch buffer.  A held lease keeps
its ring slot occupied, so the ring depth bounds how far a client can run
ahead of batch formation (backpressure, not a copy).  With
``zero_copy_serving=False`` the reactor copies each payload out
immediately (the pre-CopyEngine datapath, kept for A/B measurement) and
delivers a pre-released lease.

Replies go back **reserve-then-fill**: :meth:`Connection.reply` claims
the client's tx slot first and packs the result array straight into it
(one counted memcpy, no staging tree, descriptor meta from the channel's
structure cache).

Idle behaviour is the repo-wide hybrid policy: after an empty sweep the
reactor spins (yield-only) for ``policy.spin_us`` so a streaming client is
picked up at memcpy latency, then falls back to ``poll_interval_us``
quantum sleeps — the UMWAIT analogue, now amortized over *all* clients
instead of one blocking ``recv`` per connection.

Disconnects are part of the sweep: a connection whose peer raised its
closed flag (and whose ring is fully drained) is reaped — leaked
bulk-heap extents force-freed (``stats.heap_reaped``), its transport
closed, its arena and heap segment unlinked — and reported through
``on_disconnect``, so client churn cannot leak arenas or heap.

Large requests arrive exactly like small ones: the channel resolves a
heap-routed message into extent-backed views, so the lease handed to
``on_message`` is zero-copy either way, and the dispatcher's release
after batch gather is also what frees the extents (lease-based
reclamation).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.core.copyengine import SGList, get_engine
from repro.core.policy import OffloadPolicy
from repro.ft import inject as _inject
from repro.ipc.channel import PRIO_KEY, RecvLease
from repro.ipc.ring import ChannelClosed
from repro.ipc.transport import ShmTransport
from repro.obs import hwcounters as _hw
from repro.obs import trace as _trace


def _lease_bytes(items) -> int:
    """Total payload bytes of one drain pull (profiling only — called
    behind the ``PROF.enabled`` guard, never on the undisturbed path)."""
    total = 0
    for item in items:
        tree = item.tree if isinstance(item, RecvLease) else item[0]
        if isinstance(tree, dict):
            for v in tree.values():
                total += getattr(v, "nbytes", 0)
        else:
            total += getattr(tree, "nbytes", 0)
    return total


@dataclass
class Connection:
    """One registered client: its transport plus fairness accounting."""
    cid: int
    transport: ShmTransport
    received: int = 0          # messages drained from this client
    replied: int = 0           # replies sent back to this client
    inflight: int = 0          # dispatched, reply not yet sent (admission cap)
    dead: bool = False         # reply path failed: reap at the next sweep
    lane: int = 0              # SLO lane: last priority class seen on this
                               # client's wire (sweep visits low lanes first)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def begin(self) -> None:
        """Count one message as dispatched (reactor thread)."""
        with self._lock:
            self.received += 1
            self.inflight += 1

    def done(self) -> None:
        """Count one reply as sent (any completion thread)."""
        with self._lock:
            self.replied += 1
            self.inflight -= 1

    def reply(self, tree, header: dict,
              timeout_s: Optional[float] = None) -> None:
        """Send a reply on this client's transport and settle accounting.

        A reply whose payload is a single ``result`` array takes the
        reserve-then-fill fast path: the destination tx slot is claimed
        first and the array packed straight into it (one counted memcpy,
        no staging tree, no per-send descriptor pickle).  Anything else
        (error replies, odd shapes) falls back to a plain sync send.

        The timeout (default ``policy.retry.reply_timeout_s``) is
        deliberately short and a failure marks the connection dead:
        replies run on the *shared* dispatcher worker thread, so a
        vanished client whose reply ring filled up must cost at most one
        bounded stall — not a 30s head-of-line block per reply while
        every other client starves.
        """
        if timeout_s is None:
            timeout_s = self.transport.policy.retry.reply_timeout_s
        if _inject._PLANE is not None:
            _inject.stall("reactor.reply.stall")
        t0 = _trace.now() if _trace.TRACE.enabled else 0
        c0 = _hw.begin() if _hw.PROF.enabled else None
        try:
            arr = tree.get("result") if isinstance(tree, dict) else None
            if (isinstance(arr, np.ndarray) and len(tree) == 1):
                slot = self.transport.data.reserve(
                    {"result": arr}, header=header, timeout_s=timeout_s)
                with slot:
                    sg = SGList()
                    sg.add_array(arr, slot.tree["result"])
                    get_engine().run_sg(sg, tag="reply_fill")
            else:
                self.transport.send(tree, header=header, mode="sync",
                                    timeout_s=timeout_s)
        except (TimeoutError, ChannelClosed):
            self.dead = True        # unresponsive or vanished: reap it
            raise
        finally:
            self.done()
            if t0 or c0 is not None:
                rid = header.get(_trace.RID_KEY, 0) if header else 0
                rid = rid if isinstance(rid, int) else 0
                if t0:
                    _trace.emit(_trace.REPLY_FILL, t0, rid=rid)
                if c0 is not None:
                    _hw.end(c0, "reserve_fill", rid=rid,
                            nbytes=arr.nbytes
                            if isinstance(arr, np.ndarray) else 0)


@dataclass
class ReactorStats:
    """Aggregate sweep counters (per-connection detail lives on Connection)."""
    sweeps: int = 0
    messages: int = 0
    idle_sleeps: int = 0
    throttled: int = 0         # sweeps that skipped a conn at max_inflight
    disconnects: int = 0
    errors: int = 0            # on_message raised (message dropped, loop lives)
    zero_copy_recvs: int = 0   # requests delivered as held leases (no copy)
    heap_reaped: int = 0       # leaked bulk-heap extents freed at reap time
    batched_drains: int = 0    # drain pulls that yielded >1 message at once
    stale_reaped: int = 0      # conns reaped on heartbeat staleness (crash)
    orphan_reaped: int = 0     # never-attached handshake orphans reclaimed


class Reactor:
    """Round-robin poller over many transports in a single thread.

    ``on_message(conn, lease)`` receives a
    :class:`~repro.ipc.channel.RecvLease`: ``lease.tree``/``lease.header``
    carry the request, and when ``lease.held`` the views point into the
    client's ring slot — the consumer must ``release()`` it once the
    payload is consumed (the fabric does this after batch gather).

    ``on_messages(conn, leases)``, when given, takes precedence: each
    drain pull hands over *every* message it got in one call — a client's
    coalesced frame (K sub-messages behind one ring poll, see
    :meth:`~repro.ipc.channel.DataChannel.try_recv_many`) flows into
    batch formation as one list instead of K separate callback+poll
    iterations.
    """

    def __init__(self, policy: Optional[OffloadPolicy] = None,
                 on_message: Optional[Callable[[Connection, RecvLease],
                                               None]] = None,
                 on_disconnect: Optional[Callable[[Connection], None]] = None,
                 max_drain_per_sweep: int = 8,
                 max_inflight: int = 16,
                 zero_copy: Optional[bool] = None,
                 on_messages: Optional[Callable[[Connection,
                                                 list], None]] = None):
        self.policy = policy or OffloadPolicy()
        self.on_message = on_message
        self.on_messages = on_messages
        self.on_disconnect = on_disconnect
        self.max_drain_per_sweep = max_drain_per_sweep
        self.max_inflight = max_inflight
        self.zero_copy = (self.policy.zero_copy_serving if zero_copy is None
                          else zero_copy)
        self.stats = ReactorStats()
        self._conns: dict[int, Connection] = {}
        self._lock = threading.Lock()
        self._next_cid = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- registry -------------------------------------------------------------
    def add(self, transport: ShmTransport) -> Connection:
        """Register a transport; it is polled from the next sweep on."""
        with self._lock:
            conn = Connection(self._next_cid, transport)
            self._conns[conn.cid] = conn
            self._next_cid += 1
        return conn

    def connections(self) -> list[Connection]:
        """Snapshot of live connections (stable order by client id)."""
        with self._lock:
            return [self._conns[k] for k in sorted(self._conns)]

    def __len__(self) -> int:
        return len(self._conns)

    def _reap(self, conn: Connection) -> None:
        with self._lock:
            self._conns.pop(conn.cid, None)
        self.stats.disconnects += 1
        if self.on_disconnect is not None:
            self.on_disconnect(conn)
        try:
            # crash-reap leaked heap extents (a client killed mid-send or
            # holding reply leases) before teardown so the leak is counted;
            # force=True: reaped connections are dead by definition (their
            # flag is up or their reply path already failed)
            self.stats.heap_reaped += conn.transport.reap_heap(force=True)
        except Exception:
            pass
        conn.transport.close()          # creator side: unlinks the arena

    # -- the sweep ------------------------------------------------------------
    def _drain(self, conn: Connection) -> int:
        """Pull up to the fairness quantum from one connection's rx ring,
        in batched sweeps: one ``try_recv_many`` drains a whole coalesced
        frame (or several queued small messages) per poll iteration."""
        drained = 0
        while drained < self.max_drain_per_sweep and not conn.dead:
            budget = min(self.max_drain_per_sweep - drained,
                         self.max_inflight - conn.inflight)
            if budget <= 0:
                self.stats.throttled += 1
                return drained          # admission cap: leave rest in its ring
            t0 = _trace.now() if _trace.TRACE.enabled else 0
            c0 = _hw.begin() if _hw.PROF.enabled else None
            try:
                items = conn.transport.data.try_recv_many(
                    budget, copy=not self.zero_copy)
            except ChannelClosed:
                items = []
            if not items:
                break
            if t0:
                _trace.emit(_trace.REACTOR_DRAIN, t0, arg=len(items))
            if c0 is not None:
                # non-empty pulls only: metering every empty spin poll
                # would cost 2 syscalls per sweep and swamp the profile
                _hw.end(c0, "ring_poll", nbytes=_lease_bytes(items))
            if len(items) > 1:
                self.stats.batched_drains += 1
            drained += len(items)
            leases = []
            for item in items:
                if isinstance(item, RecvLease):
                    leases.append(item)
                    self.stats.zero_copy_recvs += 1
                else:                   # copy-out mode: already released
                    leases.append(RecvLease(item[0], item[1], None))
                conn.begin()
            # lane tracking: remember the most urgent priority class this
            # drain saw, so the next sweep visits this client in lane order
            prios = [p for p in ((lease.header or {}).get(PRIO_KEY, 0)
                                 for lease in leases) if isinstance(p, int)]
            if prios:
                conn.lane = min(prios)
            if self.on_messages is not None:
                try:
                    self.on_messages(conn, leases)
                except Exception:
                    # a failing batch handoff must not kill the sweep
                    # thread (which serves every client); drop the batch,
                    # settle accounting
                    for lease in leases:
                        lease.release()
                        conn.done()
                    self.stats.errors += 1
            elif self.on_message is not None:
                for lease in leases:
                    try:
                        self.on_message(conn, lease)
                    except Exception:
                        # one malformed message must not kill the sweep
                        # thread; drop it, settle accounting
                        lease.release()
                        conn.done()
                        self.stats.errors += 1
            else:
                for lease in leases:
                    lease.release()
        return drained

    def poll_once(self) -> int:
        """One fair sweep over every connection, in lane order (each
        client's last-seen priority class, then client id — a lane-0
        client is drained before best-effort lanes within every sweep,
        while the per-connection quantum still bounds any one client's
        share); returns messages drained."""
        self.stats.sweeps += 1
        total = 0
        for conn in sorted(self.connections(),
                           key=lambda c: (c.lane, c.cid)):
            tr = conn.transport
            tr.heartbeat()              # server liveness stamp (rate-limited)
            n = self._drain(conn)
            total += n
            # reap only after an *empty* drain: a closing peer's in-flight
            # messages are still delivered before the connection is torn
            # down.  A dead connection (reply path failed) is reaped
            # unconditionally — late callbacks hitting its closed transport
            # are swallowed by the dispatcher's completion containment.
            # Two liveness verdicts join the closed flag: a *crashed*
            # heartbeating client (stamps stopped: stale) and a handshake
            # orphan (registered but never attached/stamped/sent within the
            # connect deadline) — both leak arenas/extents if left alone.
            if not (conn.dead or (n == 0 and conn.inflight == 0)):
                continue
            stale = orphan = False
            if not conn.dead and not tr.peer_closed:
                if tr.peer_heartbeat_stamped:
                    stale = tr.peer_stale()
                else:
                    orphan = (conn.received == 0
                              and tr.peer_heartbeat_age_s()
                              > tr.policy.retry.connect_timeout_s)
            if conn.dead or tr.peer_closed or stale or orphan:
                if stale:
                    self.stats.stale_reaped += 1
                if orphan:
                    self.stats.orphan_reaped += 1
                self._reap(conn)
        self.stats.messages += total
        return total

    def _loop(self) -> None:
        quantum = self.policy.poll_interval_us * 1e-6
        spin_s = self.policy.spin_us * 1e-6
        spin_deadline = time.perf_counter() + spin_s
        while not self._stop.is_set():
            if self.poll_once() > 0:
                spin_deadline = time.perf_counter() + spin_s
                continue
            if time.perf_counter() < spin_deadline:
                time.sleep(0)           # spin phase: catch streamers fast
            else:
                self.stats.idle_sleeps += 1
                time.sleep(quantum)     # quantum phase: stay CPU-polite

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "Reactor":
        """Run the sweep loop in a daemon thread."""
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="rocket-reactor")
        self._thread.start()
        return self

    def close(self) -> None:
        """Stop the loop and close every registered transport."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=self.policy.retry.join_timeout_s)
            self._thread = None
        with self._lock:
            conns, self._conns = list(self._conns.values()), {}
        for conn in conns:
            conn.transport.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
