"""Multi-client rendezvous: a listener arena that hands out queue pairs.

:class:`ShmTransport` is strictly point-to-point, so serving *N* client
processes needs connection setup machinery — the paper's server-side
"connection setup" generalized from one peer to many (and the explicit
registration/discovery step the shared-memory-ROS literature shows
one-to-many topologies need).  The protocol:

- the server creates one small **rendezvous arena** whose *name* is the only
  thing clients must know (like a listening socket's address);
- a client takes the **registration mutex** (:class:`~repro.ipc.shm.ShmMutex`
  — exclusive shm creation is the only cross-process atomic we have, and the
  rings are SPSC, so registrations must be serialized), writes its request
  into the seqlock-protected **request mailbox**, and bumps the REQ counter;
- the server's accept loop sees ``REQ > ACK``, creates a dedicated
  :class:`~repro.ipc.transport.ShmTransport` arena for that client, writes
  the transport's name into the **reply mailbox**, and bumps ACK;
- the client reads the name, attaches, releases the mutex, and from then on
  talks over its private pre-mapped queue pair — the rendezvous arena is
  never touched again on the data path.

Rendezvous control-word map::

    0  alive flag (0 = listener gone: connects fail fast)
    1  REQ — registrations posted        2  ACK — registrations answered
    3  request-mailbox seqlock           4  reply-mailbox seqlock
    5  accepted-client count (stats)

User region: ``[request mailbox | reply mailbox]``, each a length-prefixed
pickled blob under its seqlock.
"""
from __future__ import annotations

import os
import pickle
import struct
import threading
import time
from typing import Callable, Optional

from repro.core.latency import LatencyModel
from repro.core.policy import OffloadPolicy
from repro.ipc.shm import SharedMemoryArena, ShmMutex, attach_retry
from repro.ipc.transport import (ShmTransport, TransportSpec, _unique_name,
                                 _W_ATTACHER_CLOSED as _W_T_ATTACHER_CLOSED)

_MAILBOX_BYTES = 4096
_W_ALIVE, _W_REQ, _W_ACK, _W_REQ_LOCK, _W_REP_LOCK, _W_ACCEPTED = range(6)
_REQ_OFF, _REP_OFF = 0, _MAILBOX_BYTES


def _write_mailbox(arena: SharedMemoryArena, lock_word: int, offset: int,
                   obj) -> None:
    """Publish one pickled blob into a mailbox under its seqlock."""
    blob = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    if len(blob) + 4 > _MAILBOX_BYTES:
        raise ValueError(f"mailbox message of {len(blob)} B too large")
    with arena.seqlock(lock_word).write():
        view = arena.view(offset, _MAILBOX_BYTES)
        struct.pack_into("<I", view, 0, len(blob))
        view[4:4 + len(blob)] = blob


def _read_mailbox(arena: SharedMemoryArena, lock_word: int, offset: int):
    """Read one pickled blob from a mailbox under torn-read protection."""
    def read():
        view = arena.view(offset, _MAILBOX_BYTES)
        (n,) = struct.unpack_from("<I", view, 0)
        return bytes(view[4:4 + n])
    return pickle.loads(arena.seqlock(lock_word).read(read))


class Listener:
    """Accept loop: turns registrations into dedicated per-client transports.

    The server side of the rendezvous protocol.  ``accept_once`` handles at
    most one pending registration (create arena → reply with its name) and
    returns the new server-side :class:`ShmTransport`, or ``None``; ``start``
    runs that in a background thread with hybrid-quantum idle sleeps, handing
    each accepted transport to ``on_accept``.

    ``max_clients`` caps *total registrations over the listener's lifetime*
    (client ids double as arena-name suffixes, so they are never reused);
    size it for churn, not just concurrency.

    Every accepted client is minted a dedicated transport from ``spec`` —
    ring arena *plus* (when ``spec.heap_extents > 0``) a per-connection
    bulk-heap segment for the large-message datapath, whose geometry
    travels in the same descriptor handshake.  Shared-memory cost is
    therefore ``concurrent_clients × spec.footprint_bytes``
    (:attr:`~repro.ipc.transport.TransportSpec.footprint_bytes`; the
    formula is spelled out in docs/ARCHITECTURE.md) — reaped clients'
    arena *and* heap segments are unlinked, so churn does not accumulate.
    """

    def __init__(self, name: Optional[str] = None,
                 spec: TransportSpec = TransportSpec(),
                 policy: Optional[OffloadPolicy] = None,
                 latency: Optional[LatencyModel] = None,
                 max_clients: int = 64,
                 on_accept: Optional[Callable[[ShmTransport], None]] = None):
        self.name = name or _unique_name("rocket-lsn")
        self.spec = spec
        self.policy = policy or OffloadPolicy()
        self.latency = latency
        self.max_clients = max_clients
        self.on_accept = on_accept
        self.accepted = 0
        # registrations answered with an error because the client's own
        # connect deadline had already passed (minting a transport for a
        # gone client would leak its arena until the orphan reaper runs)
        self.stale_registrations = 0
        self._arena = SharedMemoryArena(self.name, size=2 * _MAILBOX_BYTES,
                                        create=True)
        self._words = self._arena.control_words()
        self._words[_W_ALIVE] = 1
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._closed = False

    # -- accept side ----------------------------------------------------------
    def pending(self) -> bool:
        """True when a client has posted a registration we haven't answered."""
        return int(self._words[_W_REQ]) > int(self._words[_W_ACK])

    def accept_once(self) -> Optional[ShmTransport]:
        """Answer at most one pending registration; None when there is none."""
        if not self.pending():
            return None
        record = _read_mailbox(self._arena, _W_REQ_LOCK, _REQ_OFF)
        # stale-mailbox reclaim: the registration carries the client's own
        # connect deadline (CLOCK_MONOTONIC, cross-process comparable); a
        # record already past it belongs to a client that gave up — mint
        # no transport (it would leak until the orphan reaper), just ACK
        # with an error so the protocol stays in step
        reg_deadline = record.get("deadline_ns", 0)
        if reg_deadline and time.perf_counter_ns() > reg_deadline:
            self.stale_registrations += 1
            _write_mailbox(self._arena, _W_REP_LOCK, _REP_OFF,
                           {"error": "registration expired"})
            self._words[_W_ACK] += 1
            return None
        if self.accepted >= self.max_clients:
            reply = {"error": f"listener full ({self.max_clients} clients)"}
            transport = None
        else:
            cid = self.accepted
            transport = ShmTransport.create(
                f"{self.name}.c{cid}-{record.get('pid', 0)}",
                self.spec, policy=self.policy, latency=self.latency)
            # accept-time registration metadata (e.g. a client's lane
            # hint) rides the transport to on_accept, where the serving
            # fabric partitions clients across its reactor shards
            transport.accept_meta = record.get("meta") or {}
            reply = {"name": transport.name, "client_id": cid}
        _write_mailbox(self._arena, _W_REP_LOCK, _REP_OFF, reply)
        if transport is not None:
            self.accepted += 1
            self._words[_W_ACCEPTED] = self.accepted
        self._words[_W_ACK] += 1          # publishes the reply to the client
        if transport is not None and self.on_accept is not None:
            self.on_accept(transport)
        return transport

    def _accept_loop(self) -> None:
        quantum = self.policy.poll_interval_us * 1e-6
        while not self._stop.is_set():
            if self.accept_once() is None:
                time.sleep(quantum)

    def start(self) -> "Listener":
        """Run the accept loop in a daemon thread."""
        self._thread = threading.Thread(target=self._accept_loop, daemon=True,
                                        name="rocket-listener")
        self._thread.start()
        return self

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        """Stop accepting, mark the rendezvous dead, destroy its arena."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        self._words[_W_ALIVE] = 0
        self._words = None
        self._arena.close()
        self._arena.unlink()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def connect(listener_name: str, policy: Optional[OffloadPolicy] = None,
            latency: Optional[LatencyModel] = None,
            timeout_s: float = 30.0,
            meta: Optional[dict] = None) -> ShmTransport:
    """Client side: register with a listener, get a dedicated transport.

    Serializes with other connecting clients through the registration mutex,
    posts a request, waits for the server's ACK with short passive waits, and
    attaches to the transport the server created for us.  ``meta`` is an
    optional picklable registration dict delivered to the server's accept
    path (``transport.accept_meta``) — e.g. ``{"lane": 0}`` to hint the
    client's SLO lane at accept time.
    """
    deadline = time.perf_counter() + timeout_s

    def register(arena: SharedMemoryArena) -> dict:
        # inner frame so the numpy control-word view dies before arena.close()
        # NOTE: every raise below sheds ``words`` first — the traceback
        # would otherwise pin this frame (and the view) through
        # arena.close(), which then hits "exported pointers exist"
        words = arena.control_words()
        if int(words[_W_ALIVE]) == 0:
            del words
            raise ConnectionError(f"listener {listener_name!r} is shut down")
        # under the mutex the mailbox is ours; post and await the answer
        _write_mailbox(arena, _W_REQ_LOCK, _REQ_OFF,
                       {"pid": os.getpid(), "meta": meta,
                        # our own give-up time: lets accept_once drop the
                        # record as stale instead of minting a transport
                        # no one will ever attach
                        "deadline_ns": int(deadline * 1e9)})
        ticket = int(words[_W_REQ]) + 1
        words[_W_REQ] = ticket
        while int(words[_W_ACK]) < ticket:
            if int(words[_W_ALIVE]) == 0:
                del words
                raise ConnectionError(
                    f"listener {listener_name!r} died mid-registration")
            if time.perf_counter() > deadline:
                del words
                raise TimeoutError(
                    f"listener {listener_name!r} never answered")
            time.sleep(0.0005)
        return _read_mailbox(arena, _W_REP_LOCK, _REP_OFF)

    arena = attach_retry(listener_name, timeout_s)
    lock = ShmMutex(f"{listener_name}.lk")
    try:
        lock.acquire(timeout_s=max(deadline - time.perf_counter(), 0.001))
        try:
            reply = register(arena)
        finally:
            lock.release()
    finally:
        arena.close()
    if "error" in reply:
        raise ConnectionError(f"listener {listener_name!r} refused: "
                              f"{reply['error']}")
    try:
        return ShmTransport.attach(reply["name"], policy=policy,
                                   latency=latency,
                                   timeout_s=max(
                                       deadline - time.perf_counter(), 1.0))
    except Exception:
        # the server already minted an arena for us; raise its
        # attacher-closed flag so the reactor reaps (and unlinks) it now
        # instead of waiting out the orphan timeout — a failed connect
        # must not leak what it caused to be created
        try:
            half = attach_retry(reply["name"], 1.0)
            try:
                half.control_words()[_W_T_ATTACHER_CLOSED] = 1
            finally:
                half.close()
        except Exception:
            pass
        raise
