"""Cross-process shm transport: one arena, four rings, two typed channels.

:class:`ShmTransport` packages a full connection between exactly two
processes (the paper's client↔server queue-pair setup):

- a **data channel** per direction (numpy pytrees; slots for the common
  case, per-connection bulk-heap extents for large payloads);
- a **control channel** per direction (small slots, pickled commands);
- a **bulk-heap segment** (``<name>.h``, :mod:`repro.ipc.heap`) minted by
  the creator when ``spec.heap_extents > 0`` — the large-message
  datapath's extent arena, torn down/unlinked with the transport and
  crash-reaped (:meth:`ShmTransport.reap_heap`) when a peer dies holding
  extents;
- a geometry descriptor at the head of the arena, written by the creator
  under a seqlock and read by the attacher — so the attaching process only
  needs the *name* (connection setup = one validated attach, after which
  everything is pre-mapped and fault-free);
- per-endpoint shutdown flags (control words) that turn blocked ring waits
  into :class:`~repro.ipc.ring.ChannelClosed` instead of deadlocks.

Arena control-word map::

    0  descriptor seqlock        1 creator-closed     2 attacher-closed
    3  descriptor-ready flag
    4/5   c2s data produced/consumed        6/7   s2c data produced/consumed
    8/9   c2s ctrl produced/consumed        10/11 s2c ctrl produced/consumed
    12 creator heartbeat stamp   13 attacher heartbeat stamp

Heartbeat words carry ``time.perf_counter_ns()`` stamps (CLOCK_MONOTONIC
on Linux — one timebase for every process on the host, the same one the
tracer and deadlines use).  Each side stamps only its own word (server on
reactor sweep, client on send), so the store is the usual single-writer
aligned int64; staleness thresholds live on ``OffloadPolicy.retry``.  A
peer that *crashes* (never raises its closed flag) is detected by
:meth:`ShmTransport.peer_stale` going true — the trigger for client
reconnect and server-side connection reap.
"""
from __future__ import annotations

import os
import pickle
import struct
import time
from dataclasses import dataclass
from typing import Optional

from repro.core.latency import LatencyModel
from repro.core.policy import OffloadPolicy
from repro.ipc.channel import ControlChannel, DataChannel
from repro.ipc.heap import BulkHeap, HeapSpec
from repro.ipc.ring import Ring, RingSpec, _align
from repro.ipc.shm import SharedMemoryArena, attach_retry

_DESCR_BYTES = 4096
_W_DESCR_LOCK, _W_CREATOR_CLOSED, _W_ATTACHER_CLOSED, _W_READY = 0, 1, 2, 3
_RING_WORDS = {"c2s_data": (4, 5), "s2c_data": (6, 7),
               "c2s_ctrl": (8, 9), "s2c_ctrl": (10, 11)}
_W_HB_CREATOR, _W_HB_ATTACHER = 12, 13


@dataclass(frozen=True)
class TransportSpec:
    """Geometry of one connection: slot counts/sizes for both ring kinds
    plus the bulk-heap extents (embedded in the arena descriptor so only
    the creator chooses it).

    Slots are deliberately small now that large payloads ride the heap:
    the slot arena only has to fit descriptors and sub-threshold messages,
    so per-client footprint is ``footprint_bytes`` instead of the old
    256 MB of fully-reserved 32 MB slots.  ``heap_extents=0`` disables the
    heap (pre-heap behaviour: slot capacity caps the message size).
    """
    data_slots: int = 4
    data_slot_bytes: int = 2 << 20
    data_meta_bytes: int = 4096
    ctrl_slots: int = 8
    ctrl_slot_bytes: int = 64 << 10
    heap_extent_bytes: int = 1 << 20      # bulk-heap base extent (pow2)
    heap_extents: int = 32                # per direction; 0 disables

    @property
    def data_ring(self) -> RingSpec:
        """Ring geometry for the two data directions."""
        return RingSpec(self.data_slots, self.data_slot_bytes,
                        self.data_meta_bytes)

    @property
    def ctrl_ring(self) -> RingSpec:
        """Ring geometry for the two control directions."""
        return RingSpec(self.ctrl_slots, self.ctrl_slot_bytes, 64)

    @property
    def heap(self) -> HeapSpec:
        """Bulk-heap geometry (``enabled`` False when heap_extents=0)."""
        return HeapSpec(self.heap_extent_bytes, self.heap_extents)

    def layout(self) -> dict:
        """Ring name → arena user-region offset (descriptor block first)."""
        off = _align(_DESCR_BYTES)
        out = {}
        for name, spec in (("c2s_data", self.data_ring),
                           ("s2c_data", self.data_ring),
                           ("c2s_ctrl", self.ctrl_ring),
                           ("s2c_ctrl", self.ctrl_ring)):
            out[name] = off
            off = _align(off + spec.region_bytes)
        out["__total__"] = off
        return out

    @property
    def footprint_bytes(self) -> int:
        """Total shared memory one connection maps (ring arena + heap
        segment) — the per-client cost a listener multiplies by
        ``max_clients`` (see docs/ARCHITECTURE.md for the formula)."""
        total = self.layout()["__total__"]
        if self.heap.enabled:
            total += self.heap.layout()["__total__"]
        return total


def _unique_name(prefix: str = "rocket") -> str:
    return f"{prefix}-{os.getpid()}-{time.monotonic_ns() & 0xFFFFFF:x}"


class ShmTransport:
    """One endpoint of a two-process shared-memory connection."""

    def __init__(self, arena: SharedMemoryArena, spec: TransportSpec,
                 side: str, policy: Optional[OffloadPolicy] = None,
                 latency: Optional[LatencyModel] = None,
                 heap: Optional[BulkHeap] = None):
        assert side in ("creator", "attacher")
        self.arena = arena
        self.spec = spec
        self.side = side
        self.policy = policy or OffloadPolicy()
        self.latency = latency or LatencyModel()
        self.heap = heap
        self._closed = False

        layout = spec.layout()
        words = arena.control_words()
        # my tx is c2s when I created the arena ("server" side of the name)
        tx_dir, rx_dir = (("c2s", "s2c") if side == "creator"
                          else ("s2c", "c2s"))

        def ring(direction: str, kind: str) -> Ring:
            key = f"{direction}_{kind}"
            rspec = spec.data_ring if kind == "data" else spec.ctrl_ring
            r = Ring(arena, layout[key], rspec, self.policy, self.latency,
                     counter_words=_RING_WORDS[key])
            peer_word = (_W_ATTACHER_CLOSED if side == "creator"
                         else _W_CREATOR_CLOSED)
            r.bind_shutdown_word(words[peer_word:peer_word + 1])
            return r

        self._rings = {
            "tx_data": ring(tx_dir, "data"), "rx_data": ring(rx_dir, "data"),
            "tx_ctrl": ring(tx_dir, "ctrl"), "rx_ctrl": ring(rx_dir, "ctrl"),
        }
        self.data = DataChannel(self._rings["tx_data"],
                                self._rings["rx_data"],
                                self.policy, self.latency, heap=heap)
        self.ctrl = ControlChannel(self._rings["tx_ctrl"],
                                   self._rings["rx_ctrl"])
        mine = (_W_CREATOR_CLOSED if side == "creator"
                else _W_ATTACHER_CLOSED)
        self._my_closed_word = words[mine:mine + 1]
        # liveness stamps: each side writes only its own word
        mine_hb = _W_HB_CREATOR if side == "creator" else _W_HB_ATTACHER
        peer_hb = _W_HB_ATTACHER if side == "creator" else _W_HB_CREATOR
        self._my_hb_word = words[mine_hb:mine_hb + 1]
        self._peer_hb_word = words[peer_hb:peer_hb + 1]
        self._last_beat = 0.0
        self._born = time.perf_counter()

    # -- construction ---------------------------------------------------------
    @classmethod
    def create(cls, name: Optional[str] = None,
               spec: TransportSpec = TransportSpec(),
               policy: Optional[OffloadPolicy] = None,
               latency: Optional[LatencyModel] = None) -> "ShmTransport":
        """Allocate the arena, publish the geometry descriptor, raise READY."""
        name = name or _unique_name()
        layout = spec.layout()
        arena = SharedMemoryArena(name, size=layout["__total__"], create=True)
        # mint the bulk-heap segment BEFORE raising READY: the attacher
        # learns heap geometry from the descriptor and maps it immediately
        heap = (BulkHeap.create(f"{name}.h", spec.heap)
                if spec.heap.enabled else None)
        # publish geometry under the descriptor seqlock, then raise READY
        blob = pickle.dumps(spec, protocol=pickle.HIGHEST_PROTOCOL)
        if len(blob) + 4 > _DESCR_BYTES:
            raise ValueError("transport spec descriptor too large")
        lock = arena.seqlock(_W_DESCR_LOCK)
        with lock.write():
            view = arena.view(0, _DESCR_BYTES)
            struct.pack_into("<I", view, 0, len(blob))
            view[4:4 + len(blob)] = blob
        arena.control_words()[_W_READY] = 1
        return cls(arena, spec, "creator", policy, latency, heap=heap)

    @classmethod
    def attach(cls, name: str, policy: Optional[OffloadPolicy] = None,
               latency: Optional[LatencyModel] = None,
               timeout_s: float = 30.0) -> "ShmTransport":
        """Open a peer's arena by name, reading geometry from its descriptor."""
        arena = attach_retry(name, timeout_s)
        words = arena.control_words()
        deadline = time.perf_counter() + timeout_s
        while int(words[_W_READY]) == 0:       # creator still writing layout
            if time.perf_counter() > deadline:
                arena.close()
                raise TimeoutError(f"transport {name!r} never became ready")
            time.sleep(0.001)

        lock = arena.seqlock(_W_DESCR_LOCK)

        def read_spec():
            view = arena.view(0, _DESCR_BYTES)
            (n,) = struct.unpack_from("<I", view, 0)
            return bytes(view[4:4 + n])

        spec = pickle.loads(lock.read(read_spec))
        heap = (BulkHeap.attach(f"{name}.h", spec.heap, timeout_s)
                if spec.heap.enabled else None)
        return cls(arena, spec, "attacher", policy, latency, heap=heap)

    # -- convenience ----------------------------------------------------------
    @property
    def name(self) -> str:
        """Arena name — the address a peer attaches by."""
        return self.arena.name

    @property
    def peer_closed(self) -> bool:
        """True once the other endpoint announced shutdown (its closed
        flag is up); in-flight ring messages may still be drainable."""
        if self._closed:
            return True
        word = (_W_ATTACHER_CLOSED if self.side == "creator"
                else _W_CREATOR_CLOSED)
        return int(self.arena.control_words()[word]) != 0

    # -- liveness (heartbeat words 12/13) -------------------------------------
    def heartbeat(self, force: bool = False) -> None:
        """Stamp my liveness word, rate-limited to
        ``policy.retry.heartbeat_interval_s`` (one clock read per call in
        the common no-op case; the server calls this every reactor sweep,
        the client on every send)."""
        now = time.perf_counter()
        if not force and \
                now - self._last_beat < self.policy.retry.heartbeat_interval_s:
            return
        self._last_beat = now
        word = self._my_hb_word
        if word is not None:
            word[0] = time.perf_counter_ns()

    @property
    def peer_heartbeat_stamped(self) -> bool:
        """True once the peer has stamped its heartbeat word at least
        once.  Liveness-based reaping keys on this: a peer that never
        heartbeats (raw transports, older clients) is never stale-reaped —
        only a peer that *was* heartbeating and stopped is presumed
        crashed."""
        word = self._peer_hb_word
        return word is not None and int(word[0]) != 0

    def peer_heartbeat_age_s(self) -> float:
        """Seconds since the peer last stamped its heartbeat word; a peer
        that never stamped is as old as this endpoint (so a connection
        whose peer never showed up still goes stale)."""
        word = self._peer_hb_word
        if word is None:
            return float("inf")
        stamp = int(word[0])
        if stamp == 0:
            return time.perf_counter() - self._born
        return max(0.0, (time.perf_counter_ns() - stamp) / 1e9)

    def peer_stale(self, stale_s: Optional[float] = None) -> bool:
        """Liveness verdict: the peer announced shutdown, or its heartbeat
        is older than ``stale_s`` (default
        ``policy.retry.heartbeat_stale_s``).  This is what distinguishes a
        *crashed* peer (flag never raised) from a merely idle one — the
        trigger for client ``reconnect()`` and server-side reap."""
        if self.peer_closed:
            return True
        if stale_s is None:
            stale_s = self.policy.retry.heartbeat_stale_s
        return self.peer_heartbeat_age_s() > stale_s

    def send(self, tree, header: Optional[dict] = None, **kw):
        """Send a pytree on the data channel (mode semantics from policy)."""
        return self.data.send(tree, header, **kw)

    def recv(self, **kw):
        """Receive ``(tree, header)`` — or a RecvLease with ``copy=False``."""
        return self.data.recv(**kw)

    def send_msg(self, obj, **kw) -> None:
        """Send a small pickled command on the control channel."""
        self.ctrl.send_msg(obj, **kw)

    def recv_msg(self, **kw):
        """Blocking receive of one control message."""
        return self.ctrl.recv_msg(**kw)

    def stats(self) -> dict:
        """Channel-, ring-, heap-, and governor-level counters for this
        endpoint."""
        out = {
            "data": self.data.stats.snapshot(),
            "rings": {k: vars(r.stats) for k, r in self._rings.items()},
        }
        if self.heap is not None:
            out["heap"] = self.heap.stats.snapshot()
        if self.data.governor is not None:
            out["governor"] = self.data.governor.snapshot()
        return out

    def metrics(self) -> dict:
        """The same counters as :meth:`stats`, flattened to dot-keys via
        the unified :class:`~repro.obs.metrics.MetricsRegistry` shape
        (``"data.sends"``, ``"rings.tx_data.polls"``, ...) — one flat dict
        a dashboard or benchmark row can diff with
        :meth:`~repro.obs.metrics.MetricsRegistry.delta`."""
        from repro.obs.metrics import MetricsRegistry
        reg = MetricsRegistry()
        reg.register("", self.stats)    # empty prefix: keys start at "data."
        return reg.snapshot()

    # -- lifecycle ------------------------------------------------------------
    def announce_close(self) -> None:
        """Raise this endpoint's closed flag so the peer's blocked ring
        waits fail fast with ChannelClosed (no deadlock on shutdown)."""
        if self._my_closed_word is not None:
            self._my_closed_word[0] = 1

    def reap_heap(self, force: bool = False) -> int:
        """Crash-reap leaked bulk-heap extents after the peer died: frees
        both the extents *we* allocated that the dead receiver will never
        release (our tx direction) and the dead sender's half-filled,
        never-published allocations (our rx direction — only safe because
        a dead peer publishes nothing more and our rx ring is drained by
        the caller).  Returns extents freed; refuses while the peer still
        looks alive unless ``force``."""
        if self.heap is None:
            return 0
        if not (force or self.peer_closed):
            raise RuntimeError("refusing to reap heap extents from a peer "
                               "that has not closed (pass force=True only "
                               "when its process is known dead)")
        return (self.heap.reap(self.heap.tx_dir)
                + self.heap.reap(self.heap.rx_dir))

    def close(self, unlink: Optional[bool] = None) -> None:
        """Announce shutdown, drop all views, unmap (creator also unlinks
        both the ring arena and the heap segment)."""
        if self._closed:
            return
        self._closed = True
        self.announce_close()
        self.data.close()
        self._my_closed_word = None
        self._my_hb_word = None
        self._peer_hb_word = None
        for r in self._rings.values():
            r.drop_views()
        try:
            self.arena.close()
        except BufferError:
            # a zero-copy lease somewhere still pins a slot view (e.g. a
            # request drained from a connection that died mid-batch); the
            # mapping drops when the lease holder releases or the process
            # exits — unlinking below is still safe (POSIX destroys the
            # segment at last unmap), so a stuck lease cannot leak shm
            pass
        do_unlink = unlink if unlink is not None else (self.side == "creator")
        if self.heap is not None:
            self.heap.close()          # same BufferError tolerance inside
            if do_unlink:
                self.heap.unlink()
        if do_unlink:
            self.arena.unlink()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
