"""Shared-memory bulk heap: extent allocator for the large-message datapath.

Fixed-slot rings cap every message at ``data_slot_bytes`` and reserve that
capacity for *every* slot, so big payloads were unsendable and big slots
wasted arena.  The :class:`BulkHeap` breaks the coupling: each connection
gets one pre-mapped heap segment next to its ring arena, large payloads are
written into heap **extents**, and the ring then carries only a compact
extent descriptor in its meta region (descriptor-passing over shared
memory — the smart-pointer-IPC idea, with the copy itself offloaded to the
process-wide :class:`~repro.core.copyengine.CopyEngine`).

Design, in the repo's existing shared-memory discipline:

- **Two directions, one allocator each.**  The heap user region holds a
  per-direction extent-state table plus a per-direction data region
  (``c2s`` = creator-received? no — ``c2s`` is the creator's *tx*, matching
  the transport's ring naming).  Only the **sender** of a direction
  allocates from its table and only the **receiver** frees — the same
  single-writer-per-word rule the rings use, so a plain aligned int64
  store is the only atomic needed and there is no cross-process lock on
  the allocation path.
- **Extent-state words.**  One int64 per base extent: ``0`` = FREE,
  nonzero = ALLOCATED (the value is the allocation wall-clock stamp, which
  is what makes leaked extents *datable* for the crash reaper).  The
  allocator only flips FREE→ALLOCATED; the receiver only flips
  ALLOCATED→FREE; neither transition races the other.
- **Power-of-two size classes.**  An allocation of N bytes asks for a
  contiguous run of ``next_pow2(ceil(N / extent_bytes))`` base extents
  (next-fit scan).  Contiguous extents give the receiver zero-copy numpy
  views over the whole payload.
- **Multi-extent scatter lists.**  Under fragmentation the allocator falls
  back to collecting up to :data:`MAX_SEGMENTS` smaller free runs — the
  wire descriptor is then a scatter list of ``(offset, capacity)`` pairs
  and the payload's *virtual* byte range maps onto the runs in order.
  Only genuinely exhausted heaps (free extents < needed) report
  :class:`~repro.core.copyengine.WouldBlock`-style backpressure (the
  channel layer parks the send, exactly like a full ring).
- **Lease-based reclamation.**  Ownership of published extents travels
  with the message: the *receiver's* :class:`~repro.ipc.channel.RecvLease`
  release (or its copy-out unpack) frees them.  Extent lifetime is thereby
  bounded by lease lifetime, and a held lease is backpressure on the
  sender's next ``alloc`` — the bounded-queue-pair story, sized in bytes
  instead of slots.
- **Crash reap.**  A peer that dies holding leases (or mid-fill, after
  allocating but before publishing) leaks ALLOCATED extents nobody will
  free.  :meth:`reap` force-frees a direction's extents once the peer is
  known dead (the transport's closed flag / a joined process); the
  transport calls it during teardown of reaped connections so long-lived
  servers cannot bleed heap to client churn.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.ft import inject as _inject
from repro.ipc.shm import SharedMemoryArena, attach_retry

# direction indices: match the transport's ring naming (c2s = creator tx)
DIR_C2S, DIR_S2C = 0, 1
_ALIGN = 64

#: hard cap on scatter-list length: bounds the wire descriptor (16 B per
#: segment) so heap meta always fits the ring's meta region, and bounds the
#: receive-side reassembly work for pathological fragmentation.
MAX_SEGMENTS = 32


def _align(n: int, a: int = _ALIGN) -> int:
    return (n + a - 1) // a * a


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << max(0, (n - 1).bit_length())


class HeapExhausted(Exception):
    """Not enough free extents (or too fragmented for :data:`MAX_SEGMENTS`
    segments) to satisfy an allocation *right now* — retryable
    backpressure, the heap analogue of a full ring."""


@dataclass(frozen=True)
class HeapSpec:
    """Geometry of one connection's bulk heap (both directions identical).

    ``n_extents == 0`` disables the heap entirely — the transport then
    behaves exactly like the pre-heap fixed-slot stack.
    """
    extent_bytes: int = 1 << 20       # base extent (power of two)
    n_extents: int = 32               # per direction

    def __post_init__(self):
        if self.n_extents and self.extent_bytes & (self.extent_bytes - 1):
            raise ValueError("extent_bytes must be a power of two")

    @property
    def enabled(self) -> bool:
        """True when this spec describes a real heap (n_extents > 0)."""
        return self.n_extents > 0

    @property
    def dir_bytes(self) -> int:
        """Data bytes per direction."""
        return self.n_extents * self.extent_bytes

    @property
    def table_bytes(self) -> int:
        """State-table bytes per direction (64B-aligned int64 words)."""
        return _align(self.n_extents * 8)

    def layout(self) -> dict:
        """Region name -> user-region offset, plus ``__total__``."""
        off = 0
        out = {}
        for name, nbytes in (("table0", self.table_bytes),
                             ("table1", self.table_bytes),
                             ("data0", self.dir_bytes),
                             ("data1", self.dir_bytes)):
            out[name] = off
            off = _align(off + nbytes)
        out["__total__"] = off
        return out


@dataclass
class HeapStats:
    """Per-endpoint allocator counters (local)."""
    allocs: int = 0
    scatter_allocs: int = 0      # allocations that needed a scatter list
    frees: int = 0               # free() calls (message granularity)
    exhausted: int = 0           # allocation attempts that found no room
    reaped: int = 0              # extents force-freed from dead peers
    bytes_allocated: int = 0

    def snapshot(self) -> dict:
        """Plain-dict copy for logging/benchmark rows."""
        return dict(self.__dict__)


#: wire form of one allocation: ``((offset, capacity), ...)`` pairs into the
#: direction's data region.  Payload bytes map onto segments in order; each
#: segment contributes ``min(capacity, remaining)`` virtual bytes.
Segments = Tuple[Tuple[int, int], ...]


def segments_used(segments: Sequence[Tuple[int, int]], nbytes: int
                  ) -> List[Tuple[int, int, int]]:
    """Expand a wire scatter list to ``(virtual_off, data_off, used)``
    pieces covering exactly ``nbytes`` payload bytes."""
    out, voff, remain = [], 0, nbytes
    for off, cap in segments:
        used = min(cap, remain)
        if used <= 0:
            break
        out.append((voff, off, used))
        voff += used
        remain -= used
    if remain > 0:
        raise ValueError(f"scatter list covers {nbytes - remain} of "
                         f"{nbytes} payload bytes")
    return out


class BulkHeap:
    """One endpoint of a two-direction cross-process extent heap.

    Construct via :meth:`create`/:meth:`attach` (the transport does this);
    ``side`` decides which direction this endpoint allocates from
    (``creator`` tx = c2s) and which it frees (its rx direction).
    """

    def __init__(self, arena: SharedMemoryArena, spec: HeapSpec, side: str):
        assert side in ("creator", "attacher")
        self.arena = arena
        self.spec = spec
        self.side = side
        self.stats = HeapStats()
        self.tx_dir = DIR_C2S if side == "creator" else DIR_S2C
        self.rx_dir = DIR_S2C if side == "creator" else DIR_C2S
        lay = spec.layout()
        self._tables = [
            arena.ndarray(lay["table0"], (spec.n_extents,), np.int64),
            arena.ndarray(lay["table1"], (spec.n_extents,), np.int64),
        ]
        self._data_off = [lay["data0"], lay["data1"]]
        self._cursor = 0               # next-fit scan start (tx table only)
        # intra-process serialization of the scan-then-claim: the channel's
        # flush discipline makes concurrent allocs rare (engine WQ is FIFO,
        # inline sends flush first), but two threads reserving replies on
        # the same connection must not double-claim a free run.  Cross-
        # process needs no lock: each side allocates only its own direction.
        self._alloc_lock = threading.Lock()
        self._closed = False

    # -- construction ---------------------------------------------------------
    @classmethod
    def create(cls, name: str, spec: HeapSpec) -> "BulkHeap":
        """Allocate + pre-touch the heap segment (creator side)."""
        arena = SharedMemoryArena(name, size=spec.layout()["__total__"],
                                  create=True)
        return cls(arena, spec, "creator")

    @classmethod
    def attach(cls, name: str, spec: HeapSpec,
               timeout_s: float = 30.0) -> "BulkHeap":
        """Map a peer's heap segment; geometry comes from the transport
        descriptor (the arena itself stores no spec)."""
        return cls(attach_retry(name, timeout_s), spec, "attacher")

    # -- allocation (tx direction only) ---------------------------------------
    def _free_run_at(self, table: np.ndarray, start: int, limit: int) -> int:
        """Length of the FREE run starting at ``start`` (capped)."""
        n = 0
        while n < limit and start + n < self.spec.n_extents \
                and table[start + n] == 0:
            n += 1
        return n

    def _claim(self, table: np.ndarray, start: int, count: int,
               stamp: int) -> None:
        # sole allocator for this table: scan-then-store cannot race the
        # peer, whose only transition is ALLOCATED->FREE
        table[start:start + count] = stamp

    def try_alloc(self, nbytes: int) -> Optional[Segments]:
        """One allocation attempt; ``None`` when the heap is exhausted or
        too fragmented (retryable — the caller applies backpressure)."""
        if not self.spec.enabled:
            return None
        if nbytes <= 0:
            raise ValueError("alloc of <= 0 bytes")
        E, N = self.spec.extent_bytes, self.spec.n_extents
        need = -(-nbytes // E)
        if need > N:
            raise ValueError(
                f"allocation of {nbytes} B exceeds heap direction capacity "
                f"{N * E} B — raise heap_extents/heap_extent_bytes")
        if _inject._PLANE is not None \
                and _inject.fire("heap.exhausted") is not None:
            # forced exhaustion: report backpressure though extents are free
            self.stats.exhausted += 1
            return None
        with self._alloc_lock:
            return self._try_alloc_locked(nbytes, need)

    def _try_alloc_locked(self, nbytes: int, need: int) -> Optional[Segments]:
        E, N = self.spec.extent_bytes, self.spec.n_extents
        table = self._tables[self.tx_dir]
        stamp = max(1, int(time.time()))
        run = min(next_pow2(need), N)         # power-of-two size class
        # pass 1: one contiguous run of the rounded class (zero-copy views
        # for the receiver over the whole payload)
        for probe in range(N):
            start = (self._cursor + probe) % N
            if start + run > N:
                continue
            if self._free_run_at(table, start, run) == run:
                self._claim(table, start, run, stamp)
                self._cursor = (start + run) % N
                self.stats.allocs += 1
                self.stats.bytes_allocated += nbytes
                return ((start * E, run * E),)
        # pass 2: scatter — collect free runs in address order until the
        # *exact* need is covered (the last run is clipped, so scatter
        # doesn't over-claim under pressure)
        segs: list[tuple[int, int]] = []
        claimed: list[tuple[int, int]] = []
        remaining = need
        i = 0
        while i < N and remaining > 0 and len(segs) < MAX_SEGMENTS:
            if table[i] != 0:
                i += 1
                continue
            n = self._free_run_at(table, i, remaining)
            segs.append((i * E, n * E))
            claimed.append((i, n))
            remaining -= n
            i += n + 1                         # word after the run is busy
        if remaining > 0:                      # exhausted (or > MAX_SEGMENTS)
            self.stats.exhausted += 1
            return None
        for start, count in claimed:
            self._claim(table, start, count, stamp)
        self.stats.allocs += 1
        self.stats.scatter_allocs += 1
        self.stats.bytes_allocated += nbytes
        return tuple(segs)

    def alloc(self, nbytes: int, timeout_s: float = 30.0,
              poll_interval_s: float = 1e-4,
              abort_check: Optional[Callable[[], bool]] = None) -> Segments:
        """Blocking allocation with quantum polling — extents come back as
        the receiver releases leases, so waiting here *is* the heap's
        bounded-depth backpressure.  ``abort_check`` (e.g. "peer closed")
        turns a doomed wait into :class:`HeapExhausted` immediately."""
        deadline = time.perf_counter() + timeout_s
        while True:
            segs = self.try_alloc(nbytes)
            if segs is not None:
                return segs
            if abort_check is not None and abort_check():
                raise HeapExhausted(
                    f"peer gone while waiting for {nbytes} B of heap")
            if time.perf_counter() > deadline:
                raise TimeoutError(
                    f"bulk heap exhausted for {timeout_s}s "
                    f"({nbytes} B requested; receiver holding leases?)")
            time.sleep(poll_interval_s)

    # -- free (rx direction for received messages; tx on abort) ---------------
    def free(self, segments: Sequence[Tuple[int, int]],
             direction: Optional[int] = None) -> None:
        """Return a scatter list's extents to FREE.  Receivers free their
        rx direction (lease release / copy-out unpack); a sender frees its
        own tx direction when an allocation is abandoned before publish."""
        direction = self.rx_dir if direction is None else direction
        table = self._tables[direction]
        if table is None:
            return      # heap already closed/reaped (stale lease release)
        if _inject._PLANE is not None \
                and _inject.fire("heap.leak") is not None:
            # suppressed free: the extents stay ALLOCATED with their
            # wall-clock stamp — a datable leak for the reaper to find
            return
        E = self.spec.extent_bytes
        for off, cap in segments:
            start, count = off // E, -(-cap // E)
            table[start:start + count] = 0
        self.stats.frees += 1

    def free_extents(self, direction: int) -> int:
        """FREE extents in a direction right now (introspection/tests).
        Direction is deliberately explicit: :meth:`free` defaults to the
        *rx* side (receiver-driven reclamation is the common case) and a
        mismatched implicit default here invited silent cross-direction
        bugs."""
        return int(np.count_nonzero(self._tables[direction] == 0))

    def reap(self, direction: Optional[int] = None,
             min_age_s: float = 0.0) -> int:
        """Force-free every ALLOCATED extent in a direction (default: my
        tx — extents a dead *receiver* will never release; pass my rx to
        reap a dead *sender's* half-filled allocations).  Only call once
        the peer is known dead and the rx ring is drained — a live peer's
        in-flight extents would be corrupted.  ``min_age_s`` restricts the
        reap to stale stamps (paranoia against a peer that is merely
        slow)."""
        direction = self.tx_dir if direction is None else direction
        table = self._tables[direction]
        if table is None:
            return 0    # heap already closed: nothing left to reap
        now = time.time()
        reaped = 0
        for i in range(self.spec.n_extents):
            stamp = int(table[i])
            if stamp != 0 and now - stamp >= min_age_s:
                table[i] = 0
                reaped += 1
        self.stats.reaped += reaped
        return reaped

    # -- views ----------------------------------------------------------------
    def view(self, direction: int, offset: int, nbytes: int) -> memoryview:
        """Raw bytes of one data-region range."""
        if offset + nbytes > self.spec.dir_bytes:
            raise ValueError(f"heap view [{offset}, {offset + nbytes}) "
                             f"exceeds direction capacity "
                             f"{self.spec.dir_bytes}")
        return self.arena.view(self._data_off[direction] + offset, nbytes)

    def u8(self, direction: int, offset: int, nbytes: int) -> np.ndarray:
        """Writable uint8 numpy view of one data-region range (what the
        channel's SG entries copy into/out of)."""
        return np.frombuffer(self.view(direction, offset, nbytes), np.uint8)

    def resolve(self, direction: int, segments: Segments, voff: int,
                nbytes: int, total_nbytes: int) -> List[np.ndarray]:
        """uint8 views covering virtual payload range ``[voff, voff+nbytes)``
        of a message whose scatter list is ``segments``.  One piece means
        the range is contiguous in the heap (zero-copy viewable); more
        means the leaf straddles a segment boundary and must be
        reassembled by the caller (one counted copy)."""
        pieces: List[np.ndarray] = []
        end = voff + nbytes
        for seg_voff, data_off, used in segments_used(segments, total_nbytes):
            lo, hi = max(voff, seg_voff), min(end, seg_voff + used)
            if lo < hi:
                pieces.append(self.u8(direction,
                                      data_off + (lo - seg_voff), hi - lo))
        got = sum(p.nbytes for p in pieces)
        if got != nbytes:
            raise ValueError(f"virtual range [{voff}, {end}) resolves to "
                             f"{got} B (scatter list corrupt?)")
        return pieces

    # -- lifecycle ------------------------------------------------------------
    def drop_views(self) -> None:
        """Release the table exports so the arena can close."""
        self._tables = [None, None]

    def close(self) -> None:
        """Unmap this endpoint (unlink destroys the segment)."""
        if self._closed:
            return
        self._closed = True
        self.drop_views()
        try:
            self.arena.close()
        except BufferError:
            # an unreleased lease still pins a heap view; the mapping drops
            # when the holder releases or the process exits — unlink (below,
            # creator) is still safe: POSIX destroys at last unmap
            pass

    def unlink(self) -> None:
        """Destroy the segment (creator side)."""
        self.arena.unlink()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        if self.side == "creator":
            self.unlink()
