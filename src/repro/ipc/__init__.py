"""repro.ipc — real cross-process shared-memory IPC with ROCKET modes.

The paper's runtime, made an actual inter-process transport (see
``docs/ARCHITECTURE.md`` for the layer diagram and control-word maps):

- :mod:`repro.ipc.shm`       — pre-mapped shared-memory arenas, seqlocks,
  and the exclusive-creation cross-process mutex
- :mod:`repro.ipc.ring`      — fixed-slot SPSC rings (queue pairs, §IV-C)
- :mod:`repro.ipc.channel`   — typed numpy-pytree channels, sync/async/
  pipelined send modes with hybrid-polling completion
- :mod:`repro.ipc.heap`      — per-connection bulk heap: extent allocator
  for the large-message datapath (descriptor-passing over shared memory)
- :mod:`repro.ipc.transport` — one arena + four rings (+ heap segment)
  = one connection
- :mod:`repro.ipc.listener`  — multi-client rendezvous: registration
  mailbox + accept loop minting per-client transports
- :mod:`repro.ipc.reactor`   — one server thread multiplexing N client
  transports with round-robin fairness and admission caps
- :mod:`repro.ipc.worker`    — producer processes, the point-to-point
  dispatcher bridge, and the multi-client :class:`ServingFabric`
  (cross-client request batching)
"""
from repro.ipc.shm import SeqLock, SharedMemoryArena, ShmMutex, attach_retry
from repro.ipc.ring import ChannelClosed, Ring, RingSpec, SlotReader, SlotWriter
from repro.ipc.channel import (
    DEADLINE_KEY,
    PRIO_KEY,
    ChannelStats,
    ControlChannel,
    DataChannel,
    RecvLease,
    SendHandle,
    TxSlot,
    tree_nbytes,
)
from repro.ipc.heap import BulkHeap, HeapExhausted, HeapSpec
from repro.ipc.transport import ShmTransport, TransportSpec
from repro.ipc.listener import Listener, connect
from repro.ipc.reactor import Connection, Reactor
from repro.ipc.worker import (
    DispatcherServer,
    ProducerHandle,
    RemoteDispatcherClient,
    ServingFabric,
    make_source_from_spec,
    start_producer,
)

__all__ = [
    "BulkHeap", "ChannelClosed", "ChannelStats", "Connection", "DEADLINE_KEY",
    "PRIO_KEY",
    "ControlChannel", "DataChannel", "DispatcherServer", "HeapExhausted",
    "HeapSpec", "Listener", "ProducerHandle",
    "Reactor", "RecvLease", "RemoteDispatcherClient", "Ring", "RingSpec",
    "SendHandle", "SeqLock", "ServingFabric", "SharedMemoryArena",
    "ShmMutex", "ShmTransport", "SlotReader", "SlotWriter", "TransportSpec",
    "TxSlot", "attach_retry", "connect", "make_source_from_spec",
    "start_producer", "tree_nbytes",
]
