"""repro.ipc — real cross-process shared-memory IPC with ROCKET modes.

The paper's runtime, made an actual inter-process transport:

- :mod:`repro.ipc.shm`       — pre-mapped shared-memory arenas + seqlocks
- :mod:`repro.ipc.ring`      — fixed-slot SPSC rings (queue pairs, §IV-C)
- :mod:`repro.ipc.channel`   — typed numpy-pytree channels, sync/async/
  pipelined send modes with hybrid-polling completion
- :mod:`repro.ipc.transport` — one arena + four rings = one connection
- :mod:`repro.ipc.worker`    — producer processes and the cross-process
  dispatcher bridge (request/query across a real process boundary)
"""
from repro.ipc.shm import SeqLock, SharedMemoryArena, attach_retry
from repro.ipc.ring import ChannelClosed, Ring, RingSpec, SlotReader, SlotWriter
from repro.ipc.channel import (
    ChannelStats,
    ControlChannel,
    DataChannel,
    RecvLease,
    SendHandle,
    tree_nbytes,
)
from repro.ipc.transport import ShmTransport, TransportSpec
from repro.ipc.worker import (
    DispatcherServer,
    ProducerHandle,
    RemoteDispatcherClient,
    make_source_from_spec,
    start_producer,
)

__all__ = [
    "ChannelClosed", "ChannelStats", "ControlChannel", "DataChannel",
    "DispatcherServer", "ProducerHandle", "RecvLease",
    "RemoteDispatcherClient", "Ring", "RingSpec", "SendHandle", "SeqLock",
    "SharedMemoryArena", "ShmTransport", "SlotReader", "SlotWriter",
    "TransportSpec", "attach_retry", "make_source_from_spec",
    "start_producer", "tree_nbytes",
]
