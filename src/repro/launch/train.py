"""Training driver: config-selected architecture, ROCKET input pipeline,
checkpoint/restart, straggler monitoring.

CPU-scale example (the e2e driver deliverable):
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
      --steps 200 --batch 8 --seq 64

On a real cluster the same driver runs under the production mesh with
``--mesh single|multi`` (the dry-run proves those configurations compile).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_config, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.core import ExecutionMode, LatencyModel, OffloadPolicy
from repro.core.latency import calibrate
from repro.data import InputPipeline, SyntheticLMSource
from repro.ft import Heartbeat, RestartManager, StragglerMonitor
from repro.models import build_model
from repro.optim import adamw
from repro.sharding import api as shard_api
from repro.train import TrainConfig, init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mode", default="pipelined",
                    choices=["sync", "async", "pipelined"],
                    help="ROCKET tier-1 input movement mode")
    ap.add_argument("--movement", default="sync",
                    choices=["sync", "manual_dp", "manual_dp_bf16"],
                    help="tier-2 gradient movement (manual_dp needs an "
                         "active mesh with replicated params)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--calibrate", action="store_true",
                    help="recalibrate the latency model on this node")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    shape = ShapeConfig("cli", "train", args.seq, args.batch)

    manual_axes = ()
    if args.movement.startswith("manual_dp"):
        # manual-DP over however many devices this host has
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        shard_api.set_mesh(mesh)
        manual_axes = ("data",)
    tcfg = TrainConfig(
        opt=adamw.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                              total_steps=args.steps,
                              grad_sync_dtype="bfloat16"
                              if args.movement.endswith("bf16") else None),
        microbatches=args.microbatches,
        manual_dp_axes=manual_axes)
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))

    latency = None
    if args.calibrate:
        latency = calibrate(lambda b: jax.block_until_ready(jax.device_put(b)))
        print(f"calibrated latency model: L_fixed={latency.l_fixed_us:.1f}us "
              f"alpha={latency.alpha_us_per_mb:.2f}us/MB "
              f"(rel std {latency.rel_std:.1%})")

    policy = OffloadPolicy(mode=ExecutionMode(args.mode),
                           offload_threshold_bytes=1 << 12)
    source = SyntheticLMSource(cfg, shape, seed=0)
    pipeline = InputPipeline(source, policy, latency)

    ckpt_dir = args.ckpt_dir or os.path.join("checkpoints", cfg.name)
    cm = CheckpointManager(ckpt_dir)
    rm = RestartManager(cm, save_every=args.save_every)
    monitor = StragglerMonitor()
    hb = Heartbeat(os.path.join(ckpt_dir, "heartbeat.json"), host_id=0)

    params, opt_state = init_train_state(model, jax.random.key(0))
    start_step = 0
    latest = cm.latest_step()
    if latest is not None:
        (state, extra) = cm.restore(
            latest, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        if "data" in extra:
            pipeline.restore(extra["data"])
        start_step = latest
        print(f"resumed from step {latest}")

    t_train0 = time.perf_counter()
    for step in range(start_step, args.steps):
        t0 = time.perf_counter()
        batch = next(pipeline)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            toks = shape.tokens_per_step / dt
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"{dt*1e3:7.1f} ms/step {toks:9.0f} tok/s", flush=True)
        monitor.record_step(time.perf_counter() - t0, step)
        hb.beat(step)
        rm.maybe_save(step + 1, {"params": params, "opt": opt_state},
                      {"data": pipeline.state()})
    cm.wait()
    total = time.perf_counter() - t_train0
    print(f"done: {args.steps - start_step} steps in {total:.1f}s; "
          f"pipeline wait {pipeline.stats.wait_s:.2f}s "
          f"produce {pipeline.stats.produce_s:.2f}s; "
          f"engine {pipeline.engine.stats.snapshot()}")
    if monitor.events:
        print(f"straggler events: {monitor.events}")
    pipeline.close()


if __name__ == "__main__":
    main()
