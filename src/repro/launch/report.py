"""Render the dry-run/roofline results into markdown tables.

  PYTHONPATH=src python -m repro.launch.report [--mesh singlepod] \
      [--movement sync] [--compare zero1]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.dryrun import RESULTS_DIR


def load(mesh: str, movement: str) -> dict:
    out = {}
    for path in sorted(glob.glob(os.path.join(
            RESULTS_DIR, f"*__{mesh}__{movement}.json"))):
        with open(path) as f:
            r = json.load(f)
        out[(r["arch"], r["shape"])] = _refresh_metrics(r)
    return out


def _refresh_metrics(r: dict) -> dict:
    """Recompute derived roofline metrics from the stored raw measurements
    (costs / collective bytes / meta) under the current metric definitions."""
    if r.get("status") != "ok" or "cost" not in r:
        return r
    from repro.configs import SHAPES, get_config
    from repro.launch import hlo as hlo_mod
    from repro.launch.dryrun import _ideal_bytes, _model_flops
    cfg = get_config(r["arch"])
    shape = SHAPES[r["shape"]]
    coll = hlo_mod.CollectiveStats(
        bytes_by_op=r["collectives"]["bytes_by_op"],
        count_by_op=r["collectives"]["count_by_op"])
    rl = hlo_mod.roofline_from_analysis(
        r["cost"], coll, chips=r["roofline"]["chips"],
        model_flops=_model_flops(cfg, shape),
        ideal_bytes_per_device=_ideal_bytes(cfg, shape, r.get("meta", {})))
    r = dict(r)
    r["roofline"] = rl.as_dict()
    return r


def _fmt_s(x: float) -> str:
    if x >= 0.1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def roofline_table(recs: dict) -> str:
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MF ratio | frac | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), r in sorted(recs.items()):
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | — | — | — | "
                         f"skipped: full-attention arch |")
            continue
        if r["status"] == "error":
            lines.append(f"| {arch} | {shape} | — | — | — | — | — | — | "
                         f"ERROR {r['error'][:60]} |")
            continue
        rl = r["roofline"]
        lines.append(
            f"| {arch} | {shape} | {_fmt_s(rl['compute_s'])} | "
            f"{_fmt_s(rl['memory_s'])} | {_fmt_s(rl['collective_s'])} | "
            f"{rl['dominant']} | {rl['model_flops_ratio']:.2f} | "
            f"{rl['roofline_fraction']:.3f} |  |")
    return "\n".join(lines)


def dryrun_table(recs: dict) -> str:
    lines = [
        "| arch | shape | params | param B/dev | state B/dev | flops/dev | "
        "bytes/dev | coll B/dev | compile |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), r in sorted(recs.items()):
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | — | — | — | — | — | — | "
                         f"{r['status']} |")
            continue
        m, rl = r["meta"], r["roofline"]
        state = m.get("cache_bytes_per_device", m.get("opt_bytes_per_device", 0))
        lines.append(
            f"| {arch} | {shape} | {m['params'] / 1e9:.2f}B | "
            f"{m['param_bytes_per_device'] / 2 ** 30:.2f}G | "
            f"{state / 2 ** 30:.2f}G | "
            f"{rl['flops_per_device']:.2e} | {rl['bytes_per_device']:.2e} | "
            f"{rl['collective_bytes_per_device']:.2e} | {r['compile_s']}s |")
    return "\n".join(lines)


def compare_table(base: dict, opt: dict, label: str) -> str:
    lines = [
        f"| arch | shape | term | baseline | {label} | delta |",
        "|---|---|---|---|---|---|",
    ]
    for key in sorted(set(base) & set(opt)):
        b, o = base[key], opt[key]
        if b["status"] != "ok" or o["status"] != "ok":
            continue
        rb, ro = b["roofline"], o["roofline"]
        for term in ("collective_s", "memory_s", "compute_s",
                     "roofline_fraction"):
            if abs(rb[term]) < 1e-12 and abs(ro[term]) < 1e-12:
                continue
            delta = (ro[term] - rb[term]) / max(abs(rb[term]), 1e-12) * 100
            lines.append(
                f"| {key[0]} | {key[1]} | {term} | {rb[term]:.4g} | "
                f"{ro[term]:.4g} | {delta:+.1f}% |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="singlepod")
    ap.add_argument("--movement", default="sync")
    ap.add_argument("--compare", default=None)
    ap.add_argument("--kind", default="roofline",
                    choices=["roofline", "dryrun"])
    args = ap.parse_args()
    base = load(args.mesh, args.movement)
    if args.compare:
        opt = load(args.mesh, args.compare)
        print(compare_table(base, opt, args.compare))
    elif args.kind == "roofline":
        print(roofline_table(base))
    else:
        print(dryrun_table(base))


if __name__ == "__main__":
    main()
