"""Serving driver: batched generation server with the ROCKET dispatcher.

CPU-scale example:
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
      --requests 16 --mode pipelined
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import ExecutionMode, OffloadPolicy
from repro.models import build_model
from repro.serve import BatchedServer, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--mode", default="pipelined",
                    choices=["sync", "async", "pipelined"])
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    scfg = ServeConfig(max_len=args.prompt_len + args.new_tokens + cfg.num_patches,
                       max_batch=args.max_batch, max_new_tokens=args.new_tokens)
    policy = OffloadPolicy(mode=ExecutionMode(args.mode),
                           max_batch=args.max_batch,
                           offload_threshold_bytes=1 << 12)
    server = BatchedServer(model, params, scfg, policy)
    rng = np.random.default_rng(0)

    with server.make_dispatcher() as dispatcher:
        t0 = time.perf_counter()
        if args.mode == "sync":
            outs = [dispatcher.request(
                "generate",
                rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                mode="sync") for _ in range(args.requests)]
        else:
            jids = [dispatcher.request(
                "generate",
                rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                mode=args.mode) for _ in range(args.requests)]
            outs = [dispatcher.query(j) for j in jids]
        dt = time.perf_counter() - t0

    n_tok = sum(o.size for o in outs)
    print(f"{args.requests} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s, {dt / args.requests * 1e3:.1f} ms/req)")
    print(f"server stats: {server.stats}")
    print(f"dispatcher: batches={dispatcher.stats.batches} "
          f"mean_batch={dispatcher.stats.mean_batch:.2f} "
          f"query_polls={dispatcher.stats.query_polls}")
    server.close()


if __name__ == "__main__":
    main()
