import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_DRYRUN_XLA_EXTRA", "") +
    " --xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run: lower + compile every (architecture × shape) cell on the
production mesh, record memory/cost analysis + collective schedule + roofline
terms.  No device allocation: inputs are ShapeDtypeStructs.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
  python -m repro.launch.dryrun --all --movement sync|zero1|zero1_bf16
"""
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, cells, get_config, list_archs
from repro.configs.base import Cell
from repro.launch import hlo as hlo_mod
from repro.launch import jcost
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build_model
from repro.optim import adamw
from repro.sharding import api as shard_api
from repro.sharding import rules
from repro.train import TrainConfig, make_train_step, plan_train

RESULTS_DIR = os.environ.get("REPRO_DRYRUN_DIR",
                             os.path.join(os.path.dirname(__file__),
                                          "..", "..", "..", "experiments",
                                          "dryrun"))


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _mem_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        return {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
            "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0) or
                              getattr(ma, "temp_size_in_bytes", 0)),
        }
    except Exception as ex:                                  # pragma: no cover
        return {"error": str(ex)}


def _cost_summary(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    keep = ("flops", "bytes accessed", "transcendentals", "optimal_seconds")
    return {k: float(v) for k, v in cost.items() if k in keep}


def _sharded_bytes(spec_tree, abs_tree, mesh) -> int:
    """Analytic per-device resident bytes for a spec'd pytree."""
    total = 0
    flat_s = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))
    flat_a = jax.tree.leaves(abs_tree)
    for spec, leaf in zip(flat_s, flat_a):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        denom = 1
        for entry in tuple(spec):
            axes = entry if isinstance(entry, (tuple, list)) else (entry,)
            for a in axes:
                if a is not None:
                    denom *= mesh.shape[a]
        total += n * jnp.dtype(leaf.dtype).itemsize // max(denom, 1)
    return total


def _model_flops(cfg, shape) -> float:
    from repro.models.registry import count_flops_params
    n = count_flops_params(cfg, shape.kind)
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * n * shape.tokens_per_step


def _ideal_bytes(cfg, shape, meta) -> float:
    """Algorithmic-minimum per-device HBM traffic per step.

    train:   params fwd+bwd reads + grad write + moment read/write
    prefill: params read + cache write
    decode:  params read + full cache read + O(1) write
    """
    p = meta.get("param_bytes_per_device", 0)
    o = meta.get("opt_bytes_per_device", 0)
    c = meta.get("cache_bytes_per_device", 0)
    if shape.kind == "train":
        return 3.0 * p + 2.0 * o
    if shape.kind == "prefill":
        return p + c
    return p + c          # decode: read cache once; O(1 token) writes


MOVEMENTS = ("sync", "zero1", "zero1_bf16", "dp_only", "dp_only_zero1",
             "manual_dp", "manual_dp_bf16", "inplace", "inplace_sp",
             "inplace_q8", "tp8", "tp8_serve")


def build_lowerable(cfg, shape, mesh, movement: str = "sync"):
    """Returns (lowered, meta) for one cell under an active mesh.

    ``movement`` selects the tier-2 ROCKET mode / layout being measured:
      sync         — paper-faithful baseline (blocking all-reduce semantics)
      zero1        — moments sharded over data (reduce-scatter movement)
      zero1_bf16   — zero1 + bf16 gradient compression
      dp_only      — replicate params, model axis as extra DP (small archs)
    """
    dp_layout = movement.startswith(("dp_only", "manual_dp"))
    shard_api.set_layout("dp_only" if dp_layout else "tp")
    if shape.kind != "train" and movement != "sync" and cfg.fsdp:
        # serving holds no optimizer state: TP-only parameter sharding fits
        # and avoids per-step FSDP weight gathers (§Perf, decode cell)
        import dataclasses as _dc
        cfg = _dc.replace(cfg, fsdp=False)
    model = build_model(cfg)
    p_abs = specs_mod.params_specs(model)
    p_spec = rules.param_pspecs(cfg, p_abs)
    p_sh = _named(mesh, p_spec)
    meta = {"params": int(sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p_abs))),
            "param_bytes_per_device": _sharded_bytes(p_spec, p_abs, mesh)}

    if shape.kind == "train":
        plan = plan_train(cfg, shape)
        if os.environ.get("REPRO_MICROBATCHES"):      # hillclimb override
            import dataclasses as _dc
            plan = _dc.replace(plan,
                               microbatches=int(os.environ["REPRO_MICROBATCHES"]))
        if plan.remat != cfg.remat:
            import dataclasses
            cfg = dataclasses.replace(cfg, remat=plan.remat)
            model = build_model(cfg)
        opt = adamw.AdamWConfig(
            grad_sync_dtype="bfloat16" if movement.endswith("bf16") else None)
        manual_axes = ("pod", "data", "model") \
            if movement.startswith("manual_dp") else ()
        tcfg = TrainConfig(microbatches=plan.microbatches,
                           accum_dtype=plan.accum_dtype, opt=opt,
                           manual_dp_axes=manual_axes)
        step = make_train_step(model, tcfg)
        opt_abs = jax.eval_shape(adamw.init, p_abs)
        opt_spec = rules.opt_pspecs(p_spec, opt_abs)
        if movement in ("zero1", "zero1_bf16", "dp_only_zero1"):
            opt_spec = {
                "m": rules.zero1_respec(opt_spec["m"], p_abs),
                "v": rules.zero1_respec(opt_spec["v"], p_abs),
                "step": P(),
            }
        opt_sh = _named(mesh, opt_spec)
        batch_abs = specs_mod.input_specs(cfg, shape)
        batch_sh = _named(mesh, rules.batch_pspecs(batch_abs))
        meta["plan"] = plan.describe()
        meta["opt_bytes_per_device"] = _sharded_bytes(opt_spec, opt_abs, mesh)
        jitted = jax.jit(step,
                         in_shardings=(p_sh, opt_sh, batch_sh),
                         out_shardings=(p_sh, opt_sh, None),
                         donate_argnums=(0, 1))
        return jitted.trace(p_abs, opt_abs, batch_abs), meta

    batch_sharded = shape.global_batch % max(rules.batch_axis_size(), 1) == 0 \
        and shape.global_batch >= rules.batch_axis_size()
    logits_sh = NamedSharding(mesh, rules.logits_pspec(cfg, batch_sharded))

    if shape.kind == "prefill":
        batch_abs = specs_mod.input_specs(cfg, shape)
        batch_sh = _named(mesh, rules.batch_pspecs(batch_abs))
        fn = functools.partial(model.prefill, max_len=shape.seq_len)
        out_abs = jax.eval_shape(fn, p_abs, batch_abs)
        cache_spec = rules.cache_pspecs(cfg, out_abs[1], shape.global_batch)
        cache_sh = _named(mesh, cache_spec)
        meta["cache_bytes_per_device"] = _sharded_bytes(
            cache_spec, out_abs[1], mesh)
        jitted = jax.jit(fn, in_shardings=(p_sh, batch_sh),
                         out_shardings=(logits_sh, cache_sh))
        return jitted.trace(p_abs, batch_abs), meta

    # decode
    if movement == "inplace_q8" and cfg.family in ("dense", "moe", "vlm"):
        from repro.models import attention as attn_mod
        cache_abs = jax.eval_shape(
            lambda: attn_mod.init_kv_cache_q8(
                cfg, shape.global_batch, shape.seq_len, cfg.num_layers))
    else:
        cache_abs = specs_mod.cache_specs(model, shape)
    cache_spec = rules.cache_pspecs(cfg, cache_abs, shape.global_batch)
    cache_sh = _named(mesh, cache_spec)
    tok_abs = specs_mod.input_specs(cfg, shape)["tokens"]
    tok_sh = NamedSharding(mesh, rules.batch_pspecs({"t": tok_abs})["t"])
    meta["cache_bytes_per_device"] = _sharded_bytes(cache_spec, cache_abs, mesh)
    decode_fn = model.decode_step
    if movement in ("inplace", "inplace_sp", "inplace_q8") and cfg.family in (
            "dense", "moe", "vlm"):
        from repro.models.transformer import lm_decode_step_inplace
        sp_axis = "model" if movement == "inplace_sp" else None
        sp_batch = tuple(a for a in ("pod", "data") if a in mesh.axis_names) \
            if batch_sharded else None
        decode_fn = functools.partial(lm_decode_step_inplace, cfg=cfg,
                                      sp_axis=sp_axis, sp_batch_axes=sp_batch)
        decode_fn = lambda p, c, t, _f=decode_fn: _f(p, c, t)
    jitted = jax.jit(decode_fn,
                     in_shardings=(p_sh, cache_sh, tok_sh),
                     out_shardings=(logits_sh, cache_sh),
                     donate_argnums=(1,))
    return jitted.trace(p_abs, cache_abs, tok_abs), meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             movement: str = "sync", save: bool = True,
             force: bool = False) -> dict:
    mesh_tag = "multipod" if multi_pod else "singlepod"
    tag = f"{arch}__{shape_name}__{mesh_tag}__{movement}"
    out_path = os.path.join(RESULTS_DIR, tag + ".json")
    if save and not force and os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    from repro.configs.base import cell_skip_reason
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
              "movement": movement, "status": "ok"}
    skip = cell_skip_reason(cfg, shape)
    if skip:
        record.update(status="skipped", reason=skip)
        _save(record, out_path, save)
        return record

    t0 = time.time()
    try:
        if movement.startswith("tp8"):
            # same 256 chips, lower TP degree: activation psums shrink with
            # the per-device activation slice (§Perf prefill exploration)
            mesh = jax.make_mesh((32, 8), ("data", "model"))
        else:
            mesh = make_production_mesh(multi_pod=multi_pod)
        with shard_api.use_mesh(mesh):
            traced, meta = build_lowerable(cfg, shape, mesh, movement)
            jest = jcost.estimate_jaxpr(traced.jaxpr.jaxpr)
            lowered = traced.lower()
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            xla_cost = _cost_summary(compiled)
            mem = _mem_summary(compiled)
            coll = hlo_mod.collective_stats(compiled.as_text(),
                                            jest.depth_trips)
            # trip-count-exact logical cost (global) -> per-device share
            cost = {
                "flops": jest.flops / mesh.size,
                "bytes accessed": jest.bytes / mesh.size,
                "transcendentals": jest.transcendentals / mesh.size,
            }
            rl = hlo_mod.roofline_from_analysis(
                cost, coll, chips=mesh.size,
                model_flops=_model_flops(cfg, shape),
                ideal_bytes_per_device=_ideal_bytes(cfg, shape, meta))
            record.update(
                meta=meta, lower_s=round(t_lower, 1),
                compile_s=round(t_compile, 1),
                cost=cost, xla_cost=xla_cost, memory=mem,
                depth_trips={str(k): v for k, v in jest.depth_trips.items()},
                collectives={"bytes_by_op": coll.bytes_by_op,
                             "count_by_op": coll.count_by_op},
                roofline=rl.as_dict(),
            )
    except Exception as ex:
        record.update(status="error", error=f"{type(ex).__name__}: {ex}",
                      traceback=traceback.format_exc()[-4000:])
    _save(record, out_path, save)
    return record


def _save(record: dict, path: str, save: bool) -> None:
    if not save:
        return
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--movement", default="sync", choices=list(MOVEMENTS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    todo: list[Cell] = []
    if args.all:
        todo = cells([args.arch] if args.arch else None,
                     [args.shape] if args.shape else None)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = cells([args.arch], [args.shape])

    n_ok = n_skip = n_err = 0
    for cell in todo:
        rec = run_cell(cell.arch, cell.shape, multi_pod=args.multi_pod,
                       movement=args.movement, force=args.force)
        status = rec["status"]
        n_ok += status == "ok"
        n_skip += status == "skipped"
        n_err += status == "error"
        if status == "ok":
            rl = rec["roofline"]
            print(f"[{status:7s}] {cell.arch:24s} {cell.shape:12s} "
                  f"compile={rec['compile_s']:6.1f}s dominant={rl['dominant']:10s} "
                  f"frac={rl['roofline_fraction']:.3f}", flush=True)
        elif status == "skipped":
            print(f"[{status:7s}] {cell.arch:24s} {cell.shape:12s}", flush=True)
        else:
            print(f"[{status:7s}] {cell.arch:24s} {cell.shape:12s} "
                  f"{rec['error'][:140]}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} errors={n_err}", flush=True)
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
