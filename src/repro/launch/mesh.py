"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single-pod: 16×16 = 256 chips (v5e pod slice);
multi-pod: 2×16×16 = 512 chips with the leading ``pod`` axis extending data
parallelism across pods (ICI within a pod, DCN across pods).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(devices: int | None = None):
    """Small mesh over however many devices the test environment has."""
    n = devices or len(jax.devices())
    model = 1
    for cand in (4, 2):
        if n % cand == 0 and n >= cand:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
