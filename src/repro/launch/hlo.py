"""Compiled-HLO analysis: collective-byte accounting + roofline terms.

``cost_analysis`` gives per-device FLOPs and bytes; collective bytes are not
included, so we parse the optimized HLO text and sum the *result-shape* bytes
of every collective op (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, sync and -start forms).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

# one shape, e.g. bf16[256,1024]{1,0} or f32[]
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# an HLO instruction line: "%name = <shape(s)> <op>(" — tuple shapes may
# contain /*index=N*/ comments, so the shape group must admit '='
_INSTR_RE = re.compile(
    r"^\s*%[\w.\-]+\s*=\s*(.*?)\s+("
    + "|".join(op.replace("-", r"\-") for op in COLLECTIVE_OPS)
    + r")(?:-start|-done)?\(")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shapes_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())


_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_WHILE_BODY_RE = re.compile(r"\bwhile\(.*?\bbody=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _parse_computations(hlo_text: str):
    """Split HLO text into {comp_name: [instruction lines]}; returns
    (comps, entry_name)."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if line.startswith("  ") and cur is not None:
            s = line.strip()
            if s and not s.startswith("//"):
                comps[cur].append(s)
            continue
        m = _COMP_HEADER_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
        elif line.strip() == "}":
            cur = None
    return comps, entry


def _comp_multipliers(comps: dict, entry: str) -> dict:
    """Execution-count multiplier per computation.

    While bodies multiply by the loop's ``known_trip_count`` (recorded by XLA
    in the instruction's backend_config); every other call edge (fusion,
    to_apply, condition, branches) inherits the caller's multiplier."""
    mult = {entry: 1.0}
    stack = [entry]
    while stack:
        name = stack.pop()
        m0 = mult[name]
        for line in comps.get(name, ()):
            body_m = _WHILE_BODY_RE.search(line)
            trips = 1.0
            tm = _TRIP_RE.search(line)
            if tm:
                trips = float(tm.group(1))
            callees = _CALL_ATTR_RE.findall(line)
            br = _BRANCHES_RE.search(line)
            if br:
                callees += [c.strip().lstrip("%")
                            for c in br.group(1).split(",")]
            for c in set(callees):
                cm = m0 * trips if (body_m and c == body_m.group(1)) else m0
                if c in comps and mult.get(c, 0.0) < cm:
                    mult[c] = cm
                    stack.append(c)
    return mult


def collective_stats(hlo_text: str, depth_trips: dict | None = None
                     ) -> CollectiveStats:
    """Sum collective result bytes.  Collectives inside while bodies are
    scaled by the loop's known_trip_count (from the compiled artifact's
    backend_config), propagated through the call graph — the HLO text
    contains each loop body exactly once."""
    out = CollectiveStats()
    comps, entry = _parse_computations(hlo_text)
    mult = _comp_multipliers(comps, entry) if entry else {}
    for comp, lines in comps.items():
        trips = mult.get(comp, 1.0)
        for stripped in lines:
            if "-done(" in stripped:    # avoid double counting start/done
                continue
            m = _INSTR_RE.search(stripped)
            if not m:
                continue
            shapes_str, op = m.group(1), m.group(2)
            nbytes = _shape_bytes(shapes_str) * trips
            out.bytes_by_op[op] = out.bytes_by_op.get(op, 0) + nbytes
            out.count_by_op[op] = out.count_by_op.get(op, 0) + trips
    return out


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e target constants)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    chips: int
    model_flops: float = 0.0
    ideal_bytes_per_device: float = 0.0   # algorithmic minimum HBM traffic

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def model_flops_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def ideal_s(self) -> float:
        """The workload's own roofline: max of its minimal compute time and
        minimal memory time (decode is legitimately memory-bound — the score
        is achieved-vs-ideal on whichever resource it genuinely needs)."""
        useful_compute = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        useful_memory = self.ideal_bytes_per_device / HBM_BW
        return max(useful_compute, useful_memory)

    @property
    def roofline_fraction(self) -> float:
        """ideal time / achieved bound time — the score we hillclimb."""
        if self.bound_s <= 0:
            return 0.0
        return min(self.ideal_s / self.bound_s, 1.0)

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips, "model_flops": self.model_flops,
            "model_flops_ratio": self.model_flops_ratio,
            "ideal_bytes_per_device": self.ideal_bytes_per_device,
            "ideal_s": self.ideal_s,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_from_analysis(cost: dict, coll: CollectiveStats, chips: int,
                           model_flops: float = 0.0,
                           ideal_bytes_per_device: float = 0.0) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    cbytes = float(coll.total_bytes)
    return Roofline(
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=nbytes / HBM_BW,
        collective_s=cbytes / ICI_BW,
        flops_per_device=flops,
        bytes_per_device=nbytes,
        collective_bytes_per_device=cbytes,
        chips=chips,
        model_flops=model_flops,
        ideal_bytes_per_device=ideal_bytes_per_device,
    )
