"""Trip-count-exact cost model from the jaxpr.

XLA's ``cost_analysis`` visits while-loop bodies once, so any scan-based
program (scan-over-layers, microbatch accumulation, chunked attention) is
under-counted by the trip count.  This walker computes:

- **flops**: 2·M·N·K for dot_general/conv, 1/elem for elementwise, with
  every ``scan`` body multiplied by its static ``length`` (exact);
- **bytes**: a materialization-point traffic model — each equation's outputs
  are counted once, dot/gather/scatter inputs are counted as reads, and scan
  carries/xs/ys are charged per iteration (this is what captures e.g. the
  KV-cache round-trip through a scanned decode step);
- **depth_trips**: max enclosing-scan trip product per loop-nesting depth —
  used to scale collective bytes parsed from the compiled HLO (whose
  metadata records the ``/while/body`` nesting of each op).

Numbers are *logical* (global); divide by chip count for the perfectly
sharded per-device cost.  SPMD replication waste is visible separately via
the compiled-artifact numbers recorded next to these.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import numpy as np
from jax.extend import core as jcore

TRANSCENDENTAL = {"exp", "log", "log1p", "expm1", "tanh", "erf", "erfc",
                  "logistic", "sin", "cos", "pow", "rsqrt", "sqrt", "cbrt"}
# ops whose inputs are charged as reads (beyond the universal output charge)
READ_INPUT_PRIMS = {"dot_general", "conv_general_dilated",
                    "concatenate", "sort", "top_k",
                    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                    "argmax", "argmin", "cumsum", "cumlogsumexp"}
# in-place-friendly update ops: traffic = touched region, not the buffer
SLICE_PRIMS = {"dynamic_slice", "gather", "take"}
UPDATE_PRIMS = {"dynamic_update_slice", "scatter", "scatter-add",
                "scatter_add"}
# ops assumed layout-only / fused away (no traffic charge)
FREE_PRIMS = {"reshape", "transpose", "broadcast_in_dim", "squeeze",
              "convert_element_type", "bitcast_convert_type", "copy",
              "stop_gradient", "iota", "eq", "select_n" }


@dataclass
class CostEstimate:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    depth_trips: dict = field(default_factory=dict)     # depth -> max trips

    def scaled(self, k: float) -> "CostEstimate":
        return CostEstimate(self.flops * k, self.bytes * k,
                            self.transcendentals * k, dict(self.depth_trips))

    def add(self, other: "CostEstimate") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        self.transcendentals += other.transcendentals
        for d, t in other.depth_trips.items():
            self.depth_trips[d] = max(self.depth_trips.get(d, 1), t)


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 0


def _bytes(aval) -> int:
    try:
        return _size(aval) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lshape = eqn.invars[0].aval.shape
    rshape = eqn.invars[1].aval.shape
    batch = math.prod(lshape[i] for i in lb) if lb else 1
    contract = math.prod(lshape[i] for i in lc) if lc else 1
    lfree = math.prod(lshape[i] for i in range(len(lshape))
                      if i not in lc and i not in lb)
    rfree = math.prod(rshape[i] for i in range(len(rshape))
                      if i not in rc and i not in rb)
    return 2.0 * batch * contract * lfree * rfree


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rshape = eqn.invars[1].aval.shape
    kernel_elems = math.prod(rshape)
    feature_group = eqn.params.get("feature_group_count", 1)
    out_elems = _size(out)
    # per output element: 2 * (kernel spatial * in_channels / groups)
    dn = eqn.params.get("dimension_numbers")
    return 2.0 * out_elems * kernel_elems / max(
        rshape[dn.rhs_spec[0]] if dn else 1, 1) / max(feature_group, 1) \
        * (1 if not dn else 1)


def _sub_jaxprs(eqn):
    out = []
    for k, v in eqn.params.items():
        vals = v if isinstance(v, (list, tuple)) else [v]
        for item in vals:
            if isinstance(item, jcore.ClosedJaxpr):
                out.append(item.jaxpr)
            elif isinstance(item, jcore.Jaxpr):
                out.append(item)
    return out


def estimate_jaxpr(jaxpr, depth: int = 0, trips: float = 1.0) -> CostEstimate:
    total = CostEstimate()
    total.depth_trips[depth] = max(total.depth_trips.get(depth, 1), trips)
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        out_bytes = sum(_bytes(v.aval) for v in eqn.outvars)

        if prim == "scan":
            length = float(eqn.params["length"])
            body = eqn.params["jaxpr"].jaxpr
            sub = estimate_jaxpr(body, depth + 1, trips * length)
            total.add(sub.scaled(length))
            nc = eqn.params["num_consts"]
            ncar = eqn.params["num_carry"]
            # carries updated via slice-updates (scatter/DUS chains) stay in
            # place in the compiled while loop: only the touched region moves
            # (charged inside the body); fully-rewritten carries pay a
            # read+write round-trip per iteration.
            carry_b = 0
            producers = {v: e for e in body.eqns for v in e.outvars}
            for inv, outv in zip(body.invars[nc:nc + ncar],
                                 body.outvars[:ncar]):
                v, hops = outv, 0
                while hops < 8:                    # skip layout-only wrappers
                    p = producers.get(v)
                    if p is None or p.primitive.name not in FREE_PRIMS:
                        break
                    v, hops = p.invars[0], hops + 1
                p = producers.get(v)
                inplace = p is not None and p.primitive.name in UPDATE_PRIMS
                if not inplace and v is not inv:
                    carry_b += 2 * _bytes(outv.aval)
            xs_b = sum(_bytes(v.aval) for v in body.invars[nc + ncar:])
            ys_b = sum(_bytes(v.aval) for v in body.outvars[ncar:])
            total.bytes += length * (carry_b + xs_b + ys_b)
            for d, t in sub.depth_trips.items():
                total.depth_trips[d] = max(total.depth_trips.get(d, 1), t)
            continue

        if prim == "while":
            body = eqn.params["body_jaxpr"].jaxpr
            sub = estimate_jaxpr(body, depth + 1, trips)
            total.add(sub)          # unknown trip count: counted once
            continue

        if prim == "cond":
            subs = [estimate_jaxpr(b.jaxpr, depth, trips)
                    for b in eqn.params["branches"]]
            if subs:
                best = max(subs, key=lambda s: s.flops + s.bytes)
                total.add(best)
            continue

        if prim == "shard_map":
            # body costs are per-shard: scale by the manual shard count to
            # keep global-logical semantics
            mesh = eqn.params["mesh"]
            manual = eqn.params.get("manual_axes") or mesh.axis_names
            k = 1
            for a in manual:
                k *= dict(zip(mesh.axis_names, mesh.axis_sizes
                              if hasattr(mesh, "axis_sizes")
                              else mesh.devices.shape))[a]
            sub = estimate_jaxpr(eqn.params["jaxpr"], depth, trips)
            total.add(sub.scaled(k))
            continue

        subs = _sub_jaxprs(eqn)
        if subs:                    # pjit / remat / custom_* / closed_call
            for s in subs:
                total.add(estimate_jaxpr(s, depth, trips))
            continue

        if prim == "dot_general":
            total.flops += _dot_flops(eqn)
            total.bytes += out_bytes + sum(_bytes(v.aval) for v in eqn.invars)
            continue
        if prim == "conv_general_dilated":
            total.flops += _conv_flops(eqn)
            total.bytes += out_bytes + sum(_bytes(v.aval) for v in eqn.invars)
            continue

        if prim in FREE_PRIMS:
            continue
        if prim in SLICE_PRIMS:
            # reads only the extracted region (already the output)
            total.bytes += out_bytes
            continue
        if prim in UPDATE_PRIMS:
            # in-place region write: traffic = the update operand
            # (dynamic_update_slice: invars[1]; scatter*: invars[2])
            idx = 2 if prim.startswith("scatter") and len(eqn.invars) > 2 else 1
            total.bytes += _bytes(eqn.invars[min(idx, len(eqn.invars) - 1)].aval)
            continue
        out_elems = sum(_size(v.aval) for v in eqn.outvars)
        total.flops += out_elems
        if prim in TRANSCENDENTAL:
            total.transcendentals += out_elems
        total.bytes += out_bytes
        if prim in READ_INPUT_PRIMS:
            total.bytes += sum(_bytes(v.aval) for v in eqn.invars)
    return total


def estimate_fn(fn, *abstract_args) -> CostEstimate:
    closed = jax.make_jaxpr(fn)(*abstract_args)
    return estimate_jaxpr(closed.jaxpr)
