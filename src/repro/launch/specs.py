"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the abstract batch for train/prefill
cells; decode cells additionally take the abstract cache from
``cache_specs``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.registry import ModelAPI, build_model

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.family == "audio":
            return {
                "frame_embeds": SDS((b, s, cfg.d_model), jnp.float32),
                "tokens": SDS((b, s), jnp.int32),
                "labels": SDS((b, s), jnp.int32),
            }
        if cfg.family == "vlm":
            st = s - cfg.num_patches
            return {
                "tokens": SDS((b, st), jnp.int32),
                "patch_embeds": SDS((b, cfg.num_patches, cfg.d_model), jnp.float32),
                "labels": SDS((b, st), jnp.int32),
            }
        return {"tokens": SDS((b, s), jnp.int32),
                "labels": SDS((b, s), jnp.int32)}

    if shape.kind == "prefill":
        if cfg.family == "audio":
            return {"frame_embeds": SDS((b, s, cfg.d_model), jnp.float32),
                    "tokens": SDS((b, s), jnp.int32)}
        if cfg.family == "vlm":
            return {"tokens": SDS((b, s - cfg.num_patches), jnp.int32),
                    "patch_embeds": SDS((b, cfg.num_patches, cfg.d_model),
                                        jnp.float32)}
        return {"tokens": SDS((b, s), jnp.int32)}

    # decode: one new token against a cache of length seq_len
    return {"tokens": SDS((b, 1), jnp.int32)}


def cache_specs(model: ModelAPI, shape: ShapeConfig):
    """Abstract decode cache (KV / recurrent state) for a decode cell."""
    cfg = model.cfg
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        return jax.eval_shape(
            lambda: model.init_cache(b, s, src_len=s))
    return jax.eval_shape(lambda: model.init_cache(b, s))


def params_specs(model: ModelAPI):
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))
