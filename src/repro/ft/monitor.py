"""Fault tolerance: heartbeats, straggler detection, restart management.

On a real multi-host deployment each host runs a :class:`Heartbeat` whose
beats land on shared storage (or a coordination service); the lead host's
:class:`StragglerMonitor` watches per-step timing and flags hosts whose step
time exceeds ``threshold ×`` the rolling median (the paper's contention
analysis, §VI-B "oversubscription", applied as a detector).  The
:class:`RestartManager` wires checkpoint-on-failure + resume-from-latest,
including *elastic* resume on a different device count.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


class Heartbeat:
    """Periodic liveness beacon (file-based for shared-storage clusters)."""

    def __init__(self, path: str, host_id: int, interval_s: float = 5.0):
        self.path = path
        self.host_id = host_id
        self.interval = interval_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def beat(self, step: int = -1) -> None:
        tmp = f"{self.path}.tmp"
        with open(tmp, "w") as f:
            json.dump({"host": self.host_id, "t": time.time(), "step": step}, f)
        os.replace(tmp, self.path)

    def start(self) -> None:
        def loop():
            while not self._stop.wait(self.interval):
                self.beat()
        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)

    @staticmethod
    def is_alive(path: str, timeout_s: float) -> bool:
        try:
            with open(path) as f:
                data = json.load(f)
            return (time.time() - data["t"]) < timeout_s
        except (OSError, ValueError, KeyError):
            return False


@dataclass
class StepTimer:
    """Rolling step-time statistics for straggler detection."""
    window: int = 64
    times: deque = field(default_factory=lambda: deque(maxlen=64))

    def record(self, seconds: float) -> None:
        self.times.append(seconds)

    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0

    def p95(self) -> float:
        return float(np.percentile(list(self.times), 95)) if self.times else 0.0

    def p99(self) -> float:
        return float(np.percentile(list(self.times), 99)) if self.times else 0.0


class StragglerMonitor:
    """Flags slow steps/hosts; pluggable mitigation callback.

    Mitigations available to the runner:
    - log + continue (default),
    - trigger an early checkpoint (bound the lost work),
    - request host eviction / elastic re-mesh (callback to the scheduler).
    """

    def __init__(self, threshold: float = 2.0, patience: int = 3,
                 on_straggler: Optional[Callable[[dict], None]] = None):
        self.timer = StepTimer()
        self.threshold = threshold
        self.patience = patience
        self.on_straggler = on_straggler
        self.consecutive_slow = 0
        self.events: list[dict] = []

    def record_step(self, seconds: float, step: int = -1) -> bool:
        med = self.timer.median()
        self.timer.record(seconds)
        is_slow = bool(med > 0 and seconds > self.threshold * med)
        if is_slow:
            self.consecutive_slow += 1
            if self.consecutive_slow >= self.patience:
                ev = {"step": step, "seconds": seconds, "median": med,
                      "ratio": seconds / med}
                self.events.append(ev)
                if self.on_straggler:
                    self.on_straggler(ev)
                self.consecutive_slow = 0
        else:
            self.consecutive_slow = 0
        return is_slow


class SLOMonitor:
    """Live SLO watchdog over the unified metrics plane.

    Consumes any object with a ``snapshot() -> flat dict`` (in practice a
    :class:`~repro.obs.metrics.MetricsRegistry`, duck-typed to keep this
    module free of an ``obs`` import cycle) and evaluates declarative
    upper-bound rules against the *live* counters — the piece that turns
    the serving stack's SLO metrics (per-lane p95/p99, shed and miss
    counts) into violations a runner can act on, the way
    :class:`StragglerMonitor` acts on step times.

    Two rule kinds:

    - ``"max"``  — the metric's current level must stay ≤ bound
      (e.g. ``slo.p95_ms`` within the deadline);
    - ``"rate"`` — the metric's increase *since the last check* must stay
      ≤ bound (e.g. ``dispatcher.shed`` growing at most N per interval —
      lifetime counters become per-interval readings, like
      ``MetricsRegistry.delta``).

    ``check()`` returns the new violations (also appended to
    ``violations`` and reported through ``on_violation``).
    """

    def __init__(self, metrics, rules: Optional[dict] = None,
                 on_violation: Optional[Callable[[dict], None]] = None):
        self.metrics = metrics
        self.rules: dict = dict(rules or {})
        self.on_violation = on_violation
        self.checks = 0
        self.violations: list[dict] = []
        self._prev: dict = {}

    def add_rule(self, key: str, bound: float, kind: str = "max") -> None:
        """Bound one flat metric key (``kind``: ``"max"`` or ``"rate"``)."""
        if kind not in ("max", "rate"):
            raise ValueError(f"unknown SLO rule kind {kind!r}")
        self.rules[key] = (kind, float(bound))

    def check(self) -> list[dict]:
        """Evaluate every rule against a fresh snapshot; returns the new
        violations (empty = all SLOs held this interval)."""
        snap = self.metrics.snapshot()
        self.checks += 1
        new = []
        for key, rule in self.rules.items():
            kind, bound = rule if isinstance(rule, tuple) else ("max", rule)
            cur = snap.get(key)
            if isinstance(cur, bool) or not isinstance(cur, (int, float)):
                continue
            prev = self._prev.get(key)
            value = (cur - prev if kind == "rate"
                     and isinstance(prev, (int, float)) else cur)
            if value > bound:
                new.append({"key": key, "kind": kind, "value": float(value),
                            "bound": bound, "check": self.checks})
        self._prev = snap
        self.violations.extend(new)
        if self.on_violation is not None:
            for v in new:
                self.on_violation(v)
        return new

    def snapshot(self) -> dict:
        """Watchdog counters for the metrics plane itself."""
        return {"checks": self.checks, "rules": len(self.rules),
                "violations": len(self.violations)}


class RestartManager:
    """Checkpoint-on-failure + resume-from-latest orchestration."""

    def __init__(self, ckpt_manager, save_every: int = 100):
        self.ckpt = ckpt_manager
        self.save_every = save_every
        self.failures = 0

    def maybe_save(self, step: int, state: dict, extra: dict) -> None:
        if step > 0 and step % self.save_every == 0:
            self.ckpt.save_async(step, state, extra)

    def resume_or_init(self, init_fn: Callable[[], tuple],
                       like: Optional[dict] = None, shardings=None):
        """Returns (state, extra, start_step). Elastic: shardings may target
        a different mesh than the checkpoint was written under."""
        step = self.ckpt.latest_step()
        if step is None:
            state = init_fn()
            return state, {}, 0
        if like is None:
            like = init_fn()
        state, extra = self.ckpt.restore(step, like, shardings)
        return state, extra, step

    def run_with_restarts(self, build_fn, loop_fn, max_restarts: int = 3):
        """Supervision loop: (re)build state and run; on exception checkpoint
        metadata is preserved and the loop restarts from the latest step."""
        while True:
            try:
                state, extra, start = build_fn()
                return loop_fn(state, extra, start)
            except Exception:
                self.failures += 1
                if self.failures > max_restarts:
                    raise
