from repro.ft.inject import FaultPlane, FaultSpec, InjectedFault
from repro.ft.monitor import (Heartbeat, RestartManager, StepTimer,
                              StragglerMonitor)
from repro.ft.supervisor import FabricSupervisor, reclaim_segments
from repro.ft.standby import StandbyHandle, StandbyReplica, param_echo_factory

__all__ = ["FaultPlane", "FaultSpec", "InjectedFault",
           "Heartbeat", "RestartManager", "StepTimer", "StragglerMonitor",
           "FabricSupervisor", "reclaim_segments",
           "StandbyHandle", "StandbyReplica", "param_echo_factory"]
