from repro.ft.inject import FaultPlane, FaultSpec, InjectedFault
from repro.ft.monitor import (Heartbeat, RestartManager, StepTimer,
                              StragglerMonitor)
from repro.ft.supervisor import FabricSupervisor, reclaim_segments

__all__ = ["FaultPlane", "FaultSpec", "InjectedFault",
           "Heartbeat", "RestartManager", "StepTimer", "StragglerMonitor",
           "FabricSupervisor", "reclaim_segments"]
