from repro.ft.monitor import (Heartbeat, RestartManager, StepTimer,
                              StragglerMonitor)

__all__ = ["Heartbeat", "RestartManager", "StepTimer", "StragglerMonitor"]
