"""Fabric supervision: restart a crashed serving shard, reclaim its shm.

A :class:`~repro.ipc.worker.ServingFabric` that dies abruptly (killed,
OOM, ``worker.crash`` injection) leaves two kinds of wreckage behind:

- **orphaned shared memory** — the rendezvous arena, its registration
  mutex, and every per-client transport arena + bulk-heap segment the
  listener minted.  Nothing unlinks them (that was the dead process's
  job), so they pin ``/dev/shm`` pages and — worse — block a restart:
  re-creating a listener under the same rendezvous name fails while the
  stale arena file exists.
- **stranded clients** — :class:`~repro.ipc.worker.RemoteDispatcherClient`
  peers mid-request, which is the half the clients themselves solve
  (heartbeat staleness → ``reconnect()`` → idempotent replay).

:class:`FabricSupervisor` owns the server half: it runs the fabric in a
child process, watches it, and on death **reclaims every orphaned
segment under the fabric's name prefix** before spawning a fresh
incarnation under the *same* rendezvous name — so reconnecting clients
find the replacement exactly where the casualty was.  Restarts are
bounded (``max_restarts``) and counted; reclaimed segments are counted
per kind (``arenas_reclaimed`` / ``heaps_reclaimed``).  Reclaim zeroes
the dead rendezvous arena's ALIVE word *before* unlinking it, so a
client caught mid-registration fails fast (``ConnectionError`` →
its own reconnect loop) instead of spinning out its whole connect
timeout against memory nobody will ever answer.

**Warm failover** (``standby_factory``): alongside the primary the
supervisor keeps a warm standby child
(:func:`repro.ft.standby._standby_entry`) continuously replicating the
primary's state over the fabric.  On primary death the recovery path
*promotes* instead of cold-restarting: reclaim the wreckage, command the
standby to rebuild the fabric from its replicated state under the same
rendezvous name, and adopt it as the new primary — recovery cost is the
promotion handshake plus the rendezvous bind, not process spawn +
re-import + state re-initialization.  A promotion that stalls past
``promote_timeout_s`` (``standby.promote.stall``) is abandoned — the
standby is killed so it can never race the replacement for the
rendezvous bind — and the supervisor falls back to a cold restart.
Recoveries of either kind draw from one shared budget
(``restarts + promotions`` vs ``max_restarts``).

The fabric itself is built in the child by a spawn-safe **factory**
(dotted ``module:function`` called as ``factory(name, policy)`` and
returning a *started* fabric), because a live fabric holds threads and
mapped arenas that cannot cross a process boundary.  An optional
:class:`~repro.ft.inject.FaultPlane` spec is re-installed inside the
child (via the same JSON used by ``REPRO_FAULT_PLANE``), which is how
the chaos benchmark arms ``worker.crash`` in the serving process only.
"""
from __future__ import annotations

import importlib
import multiprocessing as mp
import os
import threading
import time
from typing import Optional

from repro.core.policy import OffloadPolicy
from repro.ft import inject as _inject

#: where POSIX shared memory lives on Linux (``shared_memory.SharedMemory``
#: names map 1:1 to files here; the transport's bulk heap is ``<name>.h``
#: and the listener's registration mutex is ``<name>.lk``)
SHM_DIR = "/dev/shm"


def _fabric_entry(name: str, factory_path: str, policy: OffloadPolicy,
                  plane_json: Optional[str]) -> None:
    """Child main: build the fabric via the factory and serve until killed."""
    if plane_json:
        _inject.install(_inject.FaultPlane.from_spec_json(plane_json))
    mod_name, fn_name = factory_path.split(":")
    factory = getattr(importlib.import_module(mod_name), fn_name)
    fabric = factory(name, policy)
    try:
        while True:
            time.sleep(0.1)
    except KeyboardInterrupt:
        pass
    finally:
        fabric.close()


def echo_fabric_factory(name: str, policy: OffloadPolicy):
    """Spawn-safe reference factory (``repro.ft.supervisor:echo_fabric_factory``):
    a started fabric serving ``echo`` / ``double`` / ``sum`` — what the
    chaos benchmark and the recovery tests run in the supervised child."""
    import numpy as np

    from repro.core.dispatcher import RequestDispatcher
    from repro.ipc.worker import ServingFabric

    dispatcher = RequestDispatcher(policy)
    dispatcher.register_handler("echo", lambda x: x)
    dispatcher.register_handler("double", lambda x: x * 2)
    dispatcher.register_handler("sum", lambda x: np.asarray(x).sum())
    return ServingFabric(dispatcher, name=name, policy=policy,
                         own_dispatcher=True).start()


def _mark_rendezvous_dead(name: str) -> None:
    """Zero a dead listener arena's ALIVE control word (word 0, offset 64)
    before it is unlinked.  A client killed into the registration spin —
    the server died between ``accept_once`` and the client's ACK — polls
    that word from its *own mapping*, which unlinking alone never touches
    (POSIX keeps the mapping alive); zeroing it first turns a full
    connect-timeout burn into an immediate ``ConnectionError`` the
    client's reconnect loop handles."""
    from multiprocessing import shared_memory
    try:
        seg = shared_memory.SharedMemory(name=name)
    except (FileNotFoundError, OSError, ValueError):
        return
    try:
        seg.buf[64:72] = b"\x00" * 8
    finally:
        seg.close()


def reclaim_segments(prefix: str) -> dict:
    """Unlink every ``/dev/shm`` segment belonging to fabric ``prefix``:
    the rendezvous arena itself (exact name — its ALIVE word is zeroed
    first, see :func:`_mark_rendezvous_dead`) and everything under
    ``prefix.`` (per-client arenas ``<prefix>.c<i>-<pid>``, bulk heaps
    ``*.h``, the registration mutex ``.lk``).  The dot boundary matters:
    a bare ``startswith(prefix)`` would also destroy a *sibling* fabric
    whose name merely extends ours (``rocket-a`` reclaiming
    ``rocket-ab``'s live segments).

    Returns per-kind counts: ``arenas`` (ring/rendezvous arenas and the
    registration mutex) and ``heaps`` (bulk-heap segments, ``*.h``).
    Unlinking is safe while a surviving client still maps a segment —
    POSIX keeps the mapping alive until the last unmap — so a stale
    arena never outlives its last user, it just loses its name (which is
    exactly what a same-name restart needs)."""
    counts = {"arenas": 0, "heaps": 0}
    try:
        entries = os.listdir(SHM_DIR)
    except OSError:
        return counts
    for entry in entries:
        if entry != prefix and not entry.startswith(prefix + "."):
            continue
        if entry == prefix:
            _mark_rendezvous_dead(entry)
        try:
            os.unlink(os.path.join(SHM_DIR, entry))
        except OSError:
            continue
        counts["heaps" if entry.endswith(".h") else "arenas"] += 1
    return counts


class FabricSupervisor:
    """Run a serving fabric in a child process; restart it when it dies.

    ``factory`` is a dotted ``module:function`` path resolved *in the
    child* (spawn-safe); it must return a started fabric listening under
    ``name``.  The watch loop polls the child at ``check_interval_s``;
    on death it reclaims every shm segment under the name prefix, then
    (up to ``max_restarts`` times) spawns a replacement under the same
    rendezvous name.  ``plane_json`` arms a
    :class:`~repro.ft.inject.FaultPlane` inside the child only.

    ``standby_factory`` (a dotted *restorable* factory path, called
    ``factory(name, policy, state=...)`` — e.g.
    ``repro.ft.standby:param_echo_factory``) enables warm failover: a
    standby child replicates the primary continuously and primary death
    is answered by promotion (bounded by ``promote_timeout_s``, cold
    restart as the fallback).  ``standby_plane_json`` arms a fault plane
    in the standby child only (``standby.lag``,
    ``standby.promote.stall``, and — via the primary —
    ``ckpt.shard.corrupt`` live there).
    """

    def __init__(self, name: str, factory: str,
                 policy: Optional[OffloadPolicy] = None,
                 max_restarts: int = 3,
                 check_interval_s: float = 0.05,
                 plane_json: Optional[str] = None,
                 rearm_plane: bool = False,
                 standby_factory: Optional[str] = None,
                 standby_interval_s: float = 0.2,
                 promote_timeout_s: float = 5.0,
                 standby_plane_json: Optional[str] = None,
                 ctx: Optional[mp.context.BaseContext] = None):
        self.name = name
        self.factory = factory
        self.policy = policy or OffloadPolicy()
        self.max_restarts = max_restarts
        self.check_interval_s = check_interval_s
        self.plane_json = plane_json
        # fault-plane site counters reset with each incarnation, so a
        # deterministic schedule would re-fire in every replacement child;
        # by default the plane arms the FIRST incarnation only ("the fault
        # happened once") — rearm_plane=True re-arms every restart
        self.rearm_plane = rearm_plane
        self.standby_factory = standby_factory
        self.standby_interval_s = standby_interval_s
        self.promote_timeout_s = promote_timeout_s
        self.standby_plane_json = standby_plane_json
        self._ctx = ctx or mp.get_context("spawn")
        self._proc: Optional[mp.process.BaseProcess] = None
        # command pipe of a promoted primary (closing it would make the
        # promoted child fold its fabric, so it stays open until close())
        self._proc_conn = None
        self._standby = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self.restarts = 0
        self.crashes = 0
        self.promotions = 0
        self.promote_stalls = 0
        self.arenas_reclaimed = 0
        self.heaps_reclaimed = 0
        #: recovery state machine: running → (on death) promoting →
        #: running, or failed once the shared recovery budget is spent
        self.state = "running"
        #: last successful promotion's ack (seq/digest/lag_ms/bind_ms)
        self.last_promotion: Optional[dict] = None
        #: last crash's exit code (None until the first death)
        self.last_exitcode: Optional[int] = None

    # -- lifecycle ------------------------------------------------------------
    def _spawn(self) -> None:
        plane = self.plane_json if (self.rearm_plane or self.restarts == 0) \
            else None
        self._close_proc_conn()
        self._proc = self._ctx.Process(
            target=_fabric_entry,
            args=(self.name, self.factory, self.policy, plane),
            daemon=True)
        self._proc.start()

    def _spawn_standby(self) -> None:
        if self.standby_factory is None or self._stop.is_set():
            return
        from repro.ft.standby import StandbyHandle, _standby_entry
        parent_conn, child_conn = self._ctx.Pipe()
        proc = self._ctx.Process(
            target=_standby_entry,
            args=(self.name, self.standby_factory, self.policy, child_conn,
                  self.standby_plane_json, self.standby_interval_s),
            daemon=True)
        proc.start()
        child_conn.close()
        self._standby = StandbyHandle(proc, parent_conn)

    def _close_proc_conn(self) -> None:
        conn, self._proc_conn = self._proc_conn, None
        if conn is not None:
            try:
                conn.close()
            except OSError:
                pass

    def start(self) -> "FabricSupervisor":
        """Spawn the fabric child (and standby, if any); begin watching."""
        reclaim_segments(self.name)     # a stale name blocks the bind
        self._spawn()
        self._spawn_standby()
        self._thread = threading.Thread(target=self._watch, daemon=True,
                                        name="rocket-supervisor")
        self._thread.start()
        return self

    def _try_promote(self) -> bool:
        """Hand the rendezvous to the warm standby; True on success.
        Failure (no standby / dead / stalled past ``promote_timeout_s``)
        kills the standby outright — a late waker must never race the
        cold replacement for the rendezvous bind — and reports False so
        the caller falls back to a cold restart."""
        sb, self._standby = self._standby, None
        if sb is None:
            return False
        if not sb.alive():
            sb.kill()
            return False
        self.state = "promoting"
        ack = sb.promote(self.promote_timeout_s)
        if not (ack and ack.get("ok")):
            self.promote_stalls += 1
            sb.kill()
            # a half-bound rendezvous from the aborted promotion would
            # block the cold bind
            self.reclaim()
            return False
        self.promotions += 1
        self.last_promotion = ack
        self._close_proc_conn()
        self._proc = sb.proc          # the standby is the primary now
        self._proc_conn = sb.conn     # keep open: EOF folds its fabric
        return True

    def _watch(self) -> None:
        while not self._stop.is_set():
            proc = self._proc
            if proc is not None and not proc.is_alive():
                with self._lock:
                    if self._stop.is_set():
                        break
                    self.crashes += 1
                    self.last_exitcode = proc.exitcode
                    self.reclaim()
                    if self.restarts + self.promotions >= self.max_restarts:
                        self.state = "failed"
                        break
                    if not self._try_promote():
                        self.restarts += 1
                        self._spawn()
                    if self._standby is None:
                        self._spawn_standby()   # re-cover the new primary
                    self.state = "running"
            time.sleep(self.check_interval_s)

    def reclaim(self) -> dict:
        """Reclaim orphaned segments under the fabric's name prefix now
        (also called automatically after each crash); returns counts."""
        counts = reclaim_segments(self.name)
        self.arenas_reclaimed += counts["arenas"]
        self.heaps_reclaimed += counts["heaps"]
        return counts

    def alive(self) -> bool:
        """True while the current fabric incarnation is running."""
        proc = self._proc
        return proc is not None and proc.is_alive()

    def wait_alive(self, timeout_s: float = 10.0) -> bool:
        """Block until the (possibly restarted) fabric child is running."""
        deadline = time.perf_counter() + timeout_s
        while time.perf_counter() < deadline:
            if self.alive():
                return True
            time.sleep(0.01)
        return False

    def standby_stats(self, timeout_s: float = 5.0) -> Optional[dict]:
        """Replication counters from the live standby (None without one)."""
        sb = self._standby
        return sb.stats(timeout_s) if sb is not None and sb.alive() else None

    def stats(self) -> dict:
        """Supervision counters as one flat dict."""
        sb = self._standby
        return {"restarts": self.restarts, "crashes": self.crashes,
                "promotions": self.promotions,
                "promote_stalls": self.promote_stalls,
                "state": self.state,
                "arenas_reclaimed": self.arenas_reclaimed,
                "heaps_reclaimed": self.heaps_reclaimed,
                "alive": self.alive(),
                "standby_alive": sb is not None and sb.alive(),
                "last_promotion": self.last_promotion,
                "last_exitcode": self.last_exitcode}

    def close(self, reclaim: bool = True) -> None:
        """Stop watching, terminate child + standby, optionally reclaim."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=self.policy.retry.join_timeout_s)
            self._thread = None
        with self._lock:
            sb, self._standby = self._standby, None
        if sb is not None:
            sb.kill()
        proc = self._proc
        if proc is not None and proc.is_alive():
            proc.terminate()
            proc.join(timeout=self.policy.retry.join_timeout_s)
            if proc.is_alive():
                proc.kill()
                proc.join(timeout=1.0)
        self._proc = None
        self._close_proc_conn()
        if reclaim:
            self.reclaim()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
