"""Warm-standby failover: a replica process that mirrors a serving fabric.

The PR-8 supervisor made crashes survivable, but every recovery was a
*cold* restart: spawn a fresh child, re-import the runtime, rebuild the
model state from scratch — recovery time dominated by re-initialization,
not reconnection.  This module closes that gap with diskless
state replication over the fabric itself:

- :class:`StandbyReplica` is the pull side of
  :class:`~repro.checkpoint.manager.ReplicationSource`: it connects to
  the primary as an ordinary (low-priority-lane) client and periodically
  pulls the snapshot manifest, any shards it doesn't have (CRC-verified,
  damaged shards re-pulled individually), and the small delta log
  (dedup window + breaker + service-EWMA state) — one copy per byte,
  streamed through the puller connection's bulk heap like any other
  large payload.

- :func:`_standby_entry` is the spawn-safe child main a
  :class:`~repro.ft.supervisor.FabricSupervisor` runs next to the
  primary: a replica sync loop plus a command pipe.  On ``promote`` it
  stops pulling, rebuilds the serving fabric from the replicated state
  via a **restorable factory**, and binds it under the primary's
  rendezvous name — clients ride through on PR-8 reconnect-with-replay,
  and the imported dedup window keeps the replay exactly-once.

- :func:`param_echo_factory` is the reference restorable factory
  (``factory(name, policy, state=None)``): cold-started it generates a
  deterministic parameter pytree (the expensive initialization a warm
  promotion skips); given replicated ``state`` it restores the params
  byte-identically and imports the dispatcher delta.

Fault sites drilled here: ``standby.lag`` (skip sync rounds — lag grows
deterministically), ``standby.promote.stall`` (sleep inside promote, so
the supervisor's promote timeout → cold-fallback path is testable), and
``ckpt.shard.corrupt`` on the source side (CRC containment + re-pull).
"""
from __future__ import annotations

import importlib
import json
import pickle
import threading
import time
from typing import Optional

import numpy as np

from repro.core.policy import OffloadPolicy
from repro.ft import inject as _inject

#: replication pulls ride the lowest-urgency SLO lane so snapshot traffic
#: never preempts live serving requests in batch formation
REPLICATION_LANE = 7


class StandbyReplica:
    """Pull-side replication client: mirrors a primary fabric's state.

    ``sync_once`` pulls the manifest, fetches + CRC-verifies any shards
    for a new snapshot sequence (re-pulling damaged shards up to
    ``max_shard_retries`` times each), decodes the pytree, and refreshes
    the delta log.  ``run`` loops that at ``interval_s`` until stopped.
    All pulls are bounded by ``pull_timeout_s`` so a dead primary costs
    one timed-out round, never a hang — the promote path can always
    interrupt between rounds.
    """

    def __init__(self, primary_name: str,
                 policy: Optional[OffloadPolicy] = None,
                 interval_s: float = 0.2,
                 pull_timeout_s: Optional[float] = None,
                 max_shard_retries: int = 3):
        from repro.checkpoint.manager import ShardCodec

        self.primary_name = primary_name
        self.policy = policy or OffloadPolicy()
        self.interval_s = interval_s
        self.pull_timeout_s = (pull_timeout_s if pull_timeout_s is not None
                               else max(1.0, 10 * interval_s))
        self.max_shard_retries = max_shard_retries
        self.codec = ShardCodec()        # shard size comes from the manifest
        self._client = None
        self._lock = threading.Lock()
        # replicated state (all updated atomically per completed sync)
        self.manifest: Optional[dict] = None
        self.tree = None
        self.extra: dict = {}
        self.delta: dict = {}
        self.seq = 0
        self._applied_stamp_ns = 0
        self._applied_at_ns = 0
        self.stats = {"syncs": 0, "failed_syncs": 0, "snapshots_applied": 0,
                      "shard_pulls": 0, "shard_corrupt": 0, "delta_pulls": 0,
                      "bytes_pulled": 0, "lag_skips": 0}

    # -- plumbing --------------------------------------------------------------
    def _ensure_client(self):
        from repro.ipc.worker import RemoteDispatcherClient

        if self._client is None:
            self._client = RemoteDispatcherClient.connect(
                self.primary_name, policy=self.policy, lane=REPLICATION_LANE)
        return self._client

    def _drop_client(self) -> None:
        client, self._client = self._client, None
        if client is not None:
            try:
                client.close()
            except Exception:
                pass

    def _pull(self, op: str, payload: np.ndarray) -> np.ndarray:
        """One bounded replication request (async submit + bounded query,
        so a dying primary costs ``pull_timeout_s``, not the policy's full
        query timeout)."""
        client = self._ensure_client()
        jid = client.request(op, payload, mode="async",
                             priority=REPLICATION_LANE)
        return client.query(jid, timeout=self.pull_timeout_s)

    # -- one sync round --------------------------------------------------------
    def sync_once(self) -> bool:
        """Pull manifest (+ shards if the sequence advanced) + delta;
        returns True when a full round completed.  Any failure drops the
        client (reconnected next round) and counts ``failed_syncs``."""
        from repro.checkpoint.manager import ReplicationSource

        ping = np.zeros(1, np.uint8)
        try:
            raw = self._pull(ReplicationSource.OP_MANIFEST, ping)
            manifest = json.loads(bytes(np.asarray(raw, np.uint8)))
            tree, extra = self.tree, self.extra
            if manifest["seq"] != self.seq or self.tree is None:
                shards = self._pull_shards(manifest)
                if shards is None:
                    return False                  # superseded mid-pull
                tree, extra = self.codec.decode(manifest, shards)
                self.stats["snapshots_applied"] += 1
            raw = self._pull(ReplicationSource.OP_DELTA, ping)
            delta = pickle.loads(bytes(np.asarray(raw, np.uint8)))
            self.stats["delta_pulls"] += 1
            self.stats["bytes_pulled"] += int(np.asarray(raw).nbytes)
            with self._lock:
                self.manifest, self.tree, self.extra = manifest, tree, extra
                self.delta, self.seq = delta, manifest["seq"]
                self._applied_stamp_ns = manifest["stamp_ns"]
                self._applied_at_ns = time.perf_counter_ns()
            self.stats["syncs"] += 1
            return True
        except Exception:
            self.stats["failed_syncs"] += 1
            self._drop_client()
            return False

    def _pull_shards(self, manifest: dict) -> Optional[list]:
        """Fetch every shard of ``manifest``'s sequence, CRC-verifying
        each and re-pulling damaged ones individually (bounded); None
        when the source superseded the sequence mid-transfer."""
        from repro.checkpoint.manager import ReplicationSource

        shards = []
        for idx in range(len(manifest["sizes"])):
            req = np.array([manifest["seq"], idx], np.int64)
            for _attempt in range(1 + self.max_shard_retries):
                shard = np.asarray(
                    self._pull(ReplicationSource.OP_SHARD, req), np.uint8)
                self.stats["shard_pulls"] += 1
                if shard.nbytes == 0 and manifest["sizes"][idx]:
                    return None                   # sequence superseded
                self.stats["bytes_pulled"] += int(shard.nbytes)
                if self.codec.verify(manifest, idx, shard):
                    shards.append(shard)
                    break
                self.stats["shard_corrupt"] += 1  # CRC caught it: re-pull
            else:
                raise RuntimeError(
                    f"shard {idx} failed CRC {self.max_shard_retries + 1}x")
        return shards

    # -- loop ------------------------------------------------------------------
    def run(self, stop: threading.Event) -> None:
        """Sync at ``interval_s`` until ``stop`` is set.  The
        ``standby.lag`` site skips one round per fire (sleeping
        ``stall_s``), growing replication lag deterministically."""
        while not stop.is_set():
            if _inject._PLANE is not None and _inject.stall("standby.lag"):
                self.stats["lag_skips"] += 1
            else:
                self.sync_once()
            stop.wait(self.interval_s)
        self._drop_client()

    # -- introspection ---------------------------------------------------------
    def lag_ms(self) -> float:
        """Replication lag of the applied snapshot: how far behind the
        primary's cut stamp this replica was when it applied it, plus
        the time elapsed since (CLOCK_MONOTONIC, cross-process)."""
        with self._lock:
            if not self._applied_stamp_ns:
                return float("inf")
            return (time.perf_counter_ns() - self._applied_stamp_ns) / 1e6

    def state(self) -> dict:
        """The replicated state bundle a restorable factory consumes."""
        with self._lock:
            return {"tree": self.tree, "extra": dict(self.extra),
                    "delta": dict(self.delta), "manifest": self.manifest,
                    "seq": self.seq}

    def snapshot_stats(self) -> dict:
        """Flat counters + seq/lag for the supervisor's ``stats`` pipe."""
        out = dict(self.stats)
        out["seq"] = self.seq
        out["lag_ms"] = self.lag_ms()
        return out

    def close(self) -> None:
        self._drop_client()


# ---------------------------------------------------------------------------
# spawn-safe child main + supervisor-side handle
# ---------------------------------------------------------------------------

def _standby_entry(primary_name: str, factory_path: str,
                   policy: OffloadPolicy, conn,
                   plane_json: Optional[str],
                   interval_s: float) -> None:
    """Standby child main: replicate until told to promote (or stop).

    ``conn`` is the supervisor's command pipe: ``{"cmd": "stats"}`` →
    replica counters, ``{"cmd": "promote"}`` → stop pulling, rebuild the
    fabric from the replicated state under the primary's rendezvous name
    (the supervisor has already reclaimed the dead primary's segments),
    ack with seq/digest/lag, and keep serving; ``{"cmd": "stop"}`` or a
    closed pipe → exit.
    """
    if plane_json:
        _inject.install(_inject.FaultPlane.from_spec_json(plane_json))
    replica = StandbyReplica(primary_name, policy, interval_s=interval_s)
    stop = threading.Event()
    sync_thread = threading.Thread(target=replica.run, args=(stop,),
                                   daemon=True, name="rocket-standby-sync")
    sync_thread.start()
    fabric = None
    try:
        while True:
            try:
                if not conn.poll(0.1):
                    continue
                cmd = conn.recv()
            except (EOFError, OSError):
                return                       # supervisor died: fold quietly
            kind = cmd.get("cmd")
            if kind == "stats":
                conn.send(replica.snapshot_stats())
            elif kind == "promote" and fabric is None:
                stop.set()
                # the drillable stall: a promotion wedged here exceeds the
                # supervisor's promote timeout and falls back to cold restart
                _inject.stall("standby.promote.stall")
                t0 = time.perf_counter()
                state = replica.state()
                mod_name, fn_name = factory_path.split(":")
                factory = getattr(importlib.import_module(mod_name), fn_name)
                fabric = factory(primary_name, policy,
                                 state=state if state["seq"] else None)
                conn.send({
                    "ok": True, "seq": state["seq"],
                    "digest": (state["manifest"] or {}).get("digest"),
                    "lag_ms": replica.lag_ms(),
                    "bind_ms": (time.perf_counter() - t0) * 1e3,
                    "stats": replica.snapshot_stats(),
                })
                # tear the replication client down OFF the promote critical
                # path: a sync round caught mid-pull against the dead (and
                # already-reclaimed) primary is deep in bounded
                # timeouts/reconnects, and closing through it synchronously
                # would bill those waits to the ride-through window
                threading.Thread(target=replica.close, daemon=True,
                                 name="rocket-standby-teardown").start()
            elif kind == "stop":
                return
    finally:
        stop.set()
        if fabric is not None:
            fabric.close()


class StandbyHandle:
    """Supervisor-side handle on a standby child: command pipe + process."""

    def __init__(self, proc, conn):
        self.proc = proc
        self.conn = conn

    def alive(self) -> bool:
        return self.proc.is_alive()

    def _roundtrip(self, cmd: dict, timeout_s: float) -> Optional[dict]:
        """Send one command; its reply within ``timeout_s``, else None
        (a late reply is abandoned with the pipe — callers kill the
        child after a timeout, never reuse the handle)."""
        try:
            self.conn.send(cmd)
            if self.conn.poll(timeout_s):
                return self.conn.recv()
        except (EOFError, OSError, BrokenPipeError):
            pass
        return None

    def promote(self, timeout_s: float) -> Optional[dict]:
        """Ask the standby to take over the rendezvous; ack dict on
        success (seq/digest/lag_ms/bind_ms), None on stall/death."""
        return self._roundtrip({"cmd": "promote"}, timeout_s)

    def stats(self, timeout_s: float = 5.0) -> Optional[dict]:
        return self._roundtrip({"cmd": "stats"}, timeout_s)

    def stop(self, timeout_s: float = 5.0) -> None:
        """Graceful stop, escalating to terminate/kill."""
        try:
            self.conn.send({"cmd": "stop"})
        except (OSError, BrokenPipeError, ValueError):
            pass
        self.proc.join(timeout=timeout_s)
        self.kill()

    def kill(self) -> None:
        if self.proc.is_alive():
            self.proc.terminate()
            self.proc.join(timeout=2.0)
            if self.proc.is_alive():
                self.proc.kill()
                self.proc.join(timeout=1.0)
        try:
            self.conn.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# reference restorable factory
# ---------------------------------------------------------------------------

#: deterministic parameter pytree for the reference factory: big enough
#: that replication streams real shards through the bulk heap and cold
#: initialization does real work, small enough for test-sized soaks
PARAM_SHAPES = {f"layers/w{i}": (512, 512) for i in range(8)}


def _cold_params() -> dict:
    """The expensive deterministic initialization a warm promotion skips:
    generate the parameter pytree (seeded — cold restarts are
    reproducible) and run a few warmup passes through the serving math
    so first-request latency isn't an initialization artifact."""
    rng = np.random.default_rng(0)
    params = {}
    for name, shape in PARAM_SHAPES.items():
        layer = params.setdefault(name.split("/")[0], {})
        layer[name.split("/")[1]] = rng.standard_normal(
            shape).astype(np.float32)
    x = np.ones(512, np.float32)
    for _ in range(4):                       # warmup: touch every layer
        for layer in params["layers"].values():
            x = np.tanh(layer @ x)
    return params


def param_echo_factory(name: str, policy: OffloadPolicy, state=None):
    """Restorable reference factory (``repro.ft.standby:param_echo_factory``).

    Called ``(name, policy)`` by the supervisor's cold path it builds the
    deterministic parameter pytree from scratch; called with replicated
    ``state`` by the promote path it restores the params byte-identically
    and imports the dispatcher delta (dedup window, breakers, service
    EWMAs).  Serves ``echo`` / ``double`` (soak traffic), ``psum`` (a
    state witness: the sum of every parameter), and the ``__ckpt.*``
    replication ops via an attached
    :class:`~repro.checkpoint.manager.ReplicationSource` (exposed as
    ``fabric.replication``).
    """
    from repro.checkpoint.manager import ReplicationSource
    from repro.core.dispatcher import RequestDispatcher
    from repro.ipc.worker import ServingFabric

    if state is None:
        params = _cold_params()
    else:
        params = state["tree"]
    dispatcher = RequestDispatcher(policy)
    dispatcher.register_handler("echo", lambda x: x)
    dispatcher.register_handler("double", lambda x: x * 2)
    dispatcher.register_handler(
        "psum", lambda _x: np.float64(sum(
            float(w.sum()) for w in params["layers"].values())))
    if state is not None and state.get("delta"):
        dispatcher.import_state(state["delta"])
    source = ReplicationSource(lambda: (params, {}),
                               shard_bytes=1 << 18).attach(dispatcher)
    fabric = ServingFabric(dispatcher, name=name, policy=policy,
                           own_dispatcher=True).start()
    fabric.replication = source
    return fabric
