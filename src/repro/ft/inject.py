"""Deterministic fault-injection plane for the IPC stack.

Every failure mode the reliability layer claims to survive must be
*producible on demand*, deterministically, in-process — not by racing
``os.kill`` against a heap fill and hoping.  This module provides that:
a process-global :class:`FaultPlane` holding a seeded, replayable
schedule of named injection **sites**, consulted by one-line guards
threaded through the hot paths.

Design constraints (same order as the tracing plane, `obs/trace.py`):

1. **Disabled means zero.**  No plane installed (the default) costs one
   module-attribute load + ``is None`` check per instrumented site.  No
   RNG state exists, nothing allocates.
2. **Deterministic and replayable.**  A decision is a pure function of
   ``(seed, site, n)`` where ``n`` is the site's invocation count — a
   keyed blake2s hash, stable across processes, platforms, and Python
   hash randomization.  Two planes with the same seed and spec, driven
   through the same site-hit sequence, fire identically;
   :meth:`FaultPlane.schedule_bytes` serializes the fired log so tests
   can assert byte-identical replay.
3. **Witnessed.**  Every fire is appended to an in-order log and counted
   per site, so a chaos run can report exactly which faults it exercised.

Registered sites (the instrumented guard points):

==========================  ==================================================
site                        effect at the guard
==========================  ==================================================
``ring.publish.torn``       corrupt the slot's meta bytes just before the
                            READY flip (a torn/partial publish)
``ring.publish.drop``       publish the slot as a zero-meta skip sentinel
                            (the message silently vanishes in flight)
``ring.poll.stall``         sleep ``stall_s`` inside the consumer's poll
``channel.meta.corrupt``    flip one byte of the encoded wire meta
``channel.doorbell.delay``  sleep ``stall_s`` between payload fill and the
                            doorbell (publish)
``heap.exhausted``          force ``BulkHeap.try_alloc`` to report
                            exhaustion even when extents are free
``heap.leak``               suppress one extent ``free`` — the extent leaks
                            until the stamp-based reaper reclaims it
``reactor.reply.stall``     sleep ``stall_s`` in ``Connection.reply``
``dispatcher.handler.error``  raise ``InjectedFault`` from the handler
``worker.crash``            ``os._exit(17)`` the serving process at the
                            dispatch point (crash mid-batch / mid-heap-fill)
``ckpt.shard.corrupt``      flip one byte of a checkpoint shard served to a
                            replication puller (CRC must catch it; ``arg``
                            is the XOR value, default 0xFF)
``standby.promote.stall``   sleep ``stall_s`` inside the standby's promote
                            path, before it binds the rendezvous (drills the
                            supervisor's promote timeout → cold fallback)
``standby.lag``             skip one replication sync round on the standby
                            (sleep ``stall_s`` instead of pulling), growing
                            the replication lag deterministically
==========================  ==================================================

Usage::

    plane = FaultPlane(seed=7, faults={
        "heap.exhausted": FaultSpec(at=(3,)),          # 4th alloc fails
        "channel.meta.corrupt": FaultSpec(rate=0.01),  # 1% of sends
    })
    install(plane)
    ...                      # run the workload
    uninstall()
    assert plane.fired("heap.exhausted") == 1

Spawned children do not inherit the plane automatically (counters are
per-process state); pass the plane — it pickles — or use
:func:`to_env` / :func:`maybe_install_from_env` for ``spawn`` entries
that cannot take extra arguments.
"""
from __future__ import annotations

import hashlib
import json
import os
import struct
import threading
import time
from dataclasses import dataclass

__all__ = [
    "FaultSpec",
    "FaultPlane",
    "InjectedFault",
    "install",
    "uninstall",
    "plane",
    "fire",
    "stall",
    "SITES",
    "ENV_VAR",
    "to_env",
    "maybe_install_from_env",
]

#: every name an instrumented guard may consult; ``FaultPlane`` rejects
#: unknown names at construction so a typo'd schedule fails loudly
#: instead of silently never firing.
SITES = frozenset({
    "ring.publish.torn",
    "ring.publish.drop",
    "ring.poll.stall",
    "channel.meta.corrupt",
    "channel.doorbell.delay",
    "heap.exhausted",
    "heap.leak",
    "reactor.reply.stall",
    "dispatcher.handler.error",
    "worker.crash",
    "ckpt.shard.corrupt",
    "standby.promote.stall",
    "standby.lag",
})

#: env var carrying a JSON-encoded plane spec for ``spawn`` children
#: (see :func:`to_env`).
ENV_VAR = "REPRO_FAULT_PLANE"


class InjectedFault(RuntimeError):
    """The error raised by ``dispatcher.handler.error`` fires: a stand-in
    for an arbitrary handler bug, distinguishable from real failures."""


@dataclass(frozen=True)
class FaultSpec:
    """When and how one site fires.

    ``at`` fires on exactly those 0-based invocation indices; ``rate``
    adds seeded Bernoulli fires on every other hit.  ``max_fires`` caps
    total fires (-1 = unbounded).  ``stall_s`` parameterizes the
    stall/delay sites; ``arg`` is free for site-specific use (e.g. the
    byte value XOR'd into corrupted meta).
    """
    rate: float = 0.0
    at: tuple = ()
    max_fires: int = -1
    stall_s: float = 0.0
    arg: int = 0


class FaultPlane:
    """A seeded, replayable schedule over the named injection sites."""

    def __init__(self, seed: int = 0, faults: dict | None = None):
        faults = dict(faults or {})
        unknown = set(faults) - SITES
        if unknown:
            raise ValueError(f"unknown fault site(s): {sorted(unknown)}; "
                             f"choose from {sorted(SITES)}")
        self.seed = int(seed)
        self.faults = {site: (spec if isinstance(spec, FaultSpec)
                              else FaultSpec(**spec))
                       for site, spec in faults.items()}
        self._lock = threading.Lock()
        self._hits: dict[str, int] = {}
        self._fired: dict[str, int] = {}
        self._log: list[tuple[str, int]] = []

    # -- determinism core -------------------------------------------------
    def _draw(self, site: str, n: int) -> float:
        """Uniform [0,1) draw, a pure function of (seed, site, n)."""
        h = hashlib.blake2s(f"{self.seed}:{site}:{n}".encode(),
                            digest_size=8).digest()
        return struct.unpack("<Q", h)[0] / float(1 << 64)

    def would_fire(self, site: str, n: int) -> bool:
        """Pure decision (no counters, no cap): does ``site`` fire on its
        ``n``-th hit under this seed/spec?  The replayable schedule is
        this function tabulated."""
        spec = self.faults.get(site)
        if spec is None:
            return False
        if n in spec.at:
            return True
        return spec.rate > 0.0 and self._draw(site, n) < spec.rate

    # -- hot-path entry ---------------------------------------------------
    def should(self, site: str):
        """Count one hit at ``site``; return its :class:`FaultSpec` if
        this hit fires (and log it), else ``None``."""
        spec = self.faults.get(site)
        if spec is None:
            return None
        with self._lock:
            n = self._hits.get(site, 0)
            self._hits[site] = n + 1
            if spec.max_fires >= 0 and self._fired.get(site, 0) >= spec.max_fires:
                return None
            if not self.would_fire(site, n):
                return None
            self._fired[site] = self._fired.get(site, 0) + 1
            self._log.append((site, n))
            return spec

    # -- witnesses --------------------------------------------------------
    def hits(self, site: str) -> int:
        """Times ``site`` was consulted (fired or not)."""
        return self._hits.get(site, 0)

    def fired(self, site: str) -> int:
        """Times ``site`` actually fired."""
        return self._fired.get(site, 0)

    @property
    def log(self) -> list:
        """In-order fired events as ``(site, invocation_index)``."""
        with self._lock:
            return list(self._log)

    def schedule_bytes(self) -> bytes:
        """Canonical serialization of the fired log — byte-identical
        across replays of the same seed/spec/hit-sequence."""
        return "\n".join(f"{s}:{n}" for s, n in self.log).encode()

    def counters(self) -> dict:
        """Flat ``site -> fired`` map for metrics/report plumbing."""
        with self._lock:
            return dict(self._fired)

    # -- spawn plumbing ---------------------------------------------------
    def __getstate__(self):
        # config only: counters/logs are per-process observation state
        return {"seed": self.seed, "faults": self.faults}

    def __setstate__(self, state):
        self.__init__(state["seed"], state["faults"])

    def spec_json(self) -> str:
        """JSON spec (seed + faults) for env-var transport to children."""
        return json.dumps({
            "seed": self.seed,
            "faults": {site: {"rate": s.rate, "at": list(s.at),
                              "max_fires": s.max_fires, "stall_s": s.stall_s,
                              "arg": s.arg}
                       for site, s in self.faults.items()},
        }, sort_keys=True)

    @classmethod
    def from_spec_json(cls, text: str) -> "FaultPlane":
        obj = json.loads(text)
        return cls(obj["seed"],
                   {site: FaultSpec(rate=s["rate"], at=tuple(s["at"]),
                                    max_fires=s["max_fires"],
                                    stall_s=s["stall_s"], arg=s["arg"])
                    for site, s in obj["faults"].items()})


# process-global plane; instrumented sites guard on ``_PLANE is not None``
# so the uninstalled cost is one attribute load + identity check.
_PLANE: FaultPlane | None = None


def install(p: FaultPlane) -> None:
    """Install ``p`` as this process's fault plane."""
    global _PLANE
    _PLANE = p


def uninstall() -> None:
    """Remove the installed plane (sites go back to zero-cost)."""
    global _PLANE
    _PLANE = None


def plane() -> FaultPlane | None:
    """The installed plane, or ``None``."""
    return _PLANE


def fire(site: str):
    """Hot-path guard: the installed plane's decision for one hit at
    ``site`` (its ``FaultSpec`` when firing), or ``None``."""
    p = _PLANE
    return p.should(site) if p is not None else None


def stall(site: str) -> bool:
    """Convenience for the stall/delay sites: sleep ``spec.stall_s`` if
    ``site`` fires; returns whether it fired."""
    spec = fire(site)
    if spec is None:
        return False
    if spec.stall_s > 0.0:
        time.sleep(spec.stall_s)
    return True


def to_env(p: FaultPlane, env: dict | None = None) -> dict:
    """Put ``p``'s spec into ``env`` (default ``os.environ``) so spawn
    children can pick it up via :func:`maybe_install_from_env`."""
    if env is None:
        env = os.environ
    env[ENV_VAR] = p.spec_json()
    return env


def maybe_install_from_env() -> FaultPlane | None:
    """Install a plane from :data:`ENV_VAR` if present; for ``spawn``
    entry points that cannot thread a plane argument."""
    text = os.environ.get(ENV_VAR)
    if not text:
        return None
    p = FaultPlane.from_spec_json(text)
    install(p)
    return p
