#!/usr/bin/env python
"""CI perf-capability probe: report the host's counter tier and smoke it.

Thin wrapper around :mod:`repro.obs.hwcounters`'s CLI so CI can invoke
the probe without the ``runpy`` double-import warning that
``python -m repro.obs.hwcounters`` produces (the package imports the
submodule at import time).

Exit status follows the hwcounters smoke contract: non-zero only when a
``perf-*`` tier was claimed but the smoke workload read all zeros — a
degraded tier (``rusage``/``none``) is an honestly-reported capability,
not a failure.

Usage::

    python tools/perf_probe.py --probe          # capability report
    python tools/perf_probe.py --smoke --json   # smoke + JSON artifact
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs import hwcounters  # noqa: E402


if __name__ == "__main__":
    raise SystemExit(hwcounters.main(sys.argv[1:]))
