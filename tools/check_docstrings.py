"""Docstring-coverage gate: a stdlib `interrogate --fail-under` analogue.

Walks a package directory with `ast` and counts docstrings on modules,
classes, and public functions/methods.  Exempt (mirroring interrogate's
``--ignore-init-method --ignore-nested-functions`` defaults we want):
single-underscore and dunder names (``__init__`` included — construction is
the class docstring's job), functions nested inside functions,
``@property`` setters, and ``...`` overload stubs.  Exits nonzero when
coverage falls below the threshold, listing every undocumented definition —
so the IPC layer's documentation cannot rot silently in CI.

Usage::

    python tools/check_docstrings.py src/repro/ipc --fail-under 95
"""
from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path


def _is_exempt(node: ast.AST) -> bool:
    """Private names, non-init dunders, setters, and `...` stubs are skipped."""
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
        if node.name.startswith("_"):       # private and dunder (incl __init__)
            return True
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        for deco in node.decorator_list:
            if (isinstance(deco, ast.Attribute) and deco.attr == "setter"):
                return True
        body = node.body
        if len(body) == 1 and isinstance(body[0], ast.Expr) and \
                isinstance(body[0].value, ast.Constant) and \
                body[0].value.value is Ellipsis:
            return True
    return False


def scan_file(path: Path) -> tuple[list[str], list[str]]:
    """Return (documented, undocumented) definition labels for one file."""
    tree = ast.parse(path.read_text(), filename=str(path))
    documented, missing = [], []

    def visit(node: ast.AST, prefix: str) -> None:
        kinds = (ast.Module, ast.ClassDef, ast.FunctionDef,
                 ast.AsyncFunctionDef)
        if isinstance(node, kinds):
            if isinstance(node, ast.Module):
                label = f"{path}:module"
            else:
                if _is_exempt(node):
                    return
                label = f"{path}:{prefix}{node.name}"
            (documented if ast.get_docstring(node) else missing).append(label)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return                      # nested defs are implementation
            child_prefix = ("" if isinstance(node, ast.Module)
                            else f"{prefix}{node.name}.")
            for child in node.body:
                visit(child, child_prefix)

    visit(tree, "")
    return documented, missing


def scan(root: Path) -> tuple[list[str], list[str]]:
    """Scan every ``*.py`` under ``root`` (or just ``root`` if a file)."""
    files = [root] if root.is_file() else sorted(root.rglob("*.py"))
    documented, missing = [], []
    for f in files:
        d, m = scan_file(f)
        documented += d
        missing += m
    return documented, missing


def main(argv=None) -> int:
    """CLI entry; returns the process exit code."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+", type=Path,
                    help="package directories or files to scan")
    ap.add_argument("--fail-under", type=float, default=95.0,
                    help="minimum coverage percentage (default 95)")
    args = ap.parse_args(argv)
    documented, missing = [], []
    for p in args.paths:
        d, m = scan(p)
        documented += d
        missing += m
    total = len(documented) + len(missing)
    cov = 100.0 * len(documented) / total if total else 100.0
    print(f"docstring coverage: {len(documented)}/{total} = {cov:.1f}% "
          f"(fail-under {args.fail_under:g}%)")
    if missing:
        print("undocumented:")
        for label in missing:
            print(f"  {label}")
    if cov < args.fail_under:
        print("FAIL: coverage below threshold", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
